//! Table 1: asymptotic memory and time of the four gradient methods.
//!
//! Paper's claim (units: one drift + one diffusion evaluation):
//!
//! | method                    | memory | time       |
//! |---------------------------|--------|------------|
//! | forward pathwise          | O(1)   | O(L·D)     |
//! | backprop through solver   | O(L)   | O(L)       |
//! | stochastic adjoint + path | O(L)   | O(L)       |
//! | stochastic adjoint + tree | O(1)   | O(L log L) |
//!
//! We measure live floats (tape/noise/sensitivity buffers), wall time,
//! and NFE while sweeping L, on the replicated Example 1 system (d = 10,
//! as in §7.1). The *shape* — growth exponents and who wins — is the
//! reproduction target.

use crate::adjoint::{AdjointConfig, NoiseMode};
use crate::api::{sensitivity_batch, SdeProblem, SensAlg, StepControl};
use crate::metrics::{CsvWriter, Stopwatch};
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;
use crate::sde::problems::{sample_experiment_setup, Example1};
use crate::sde::ReplicatedSde;
use crate::solvers::Method;

/// One measured row.
#[derive(Clone, Debug)]
pub struct Row {
    pub method: &'static str,
    pub steps: usize,
    /// Amortized batch wall-clock per run (reps fan across threads via
    /// `sensitivity_batch` — multi-path throughput, not single-run
    /// latency; contention can shift method ratios vs the paper's
    /// per-run timing, so compare growth exponents, not absolutes).
    pub seconds: f64,
    pub memory_floats: usize,
    pub nfe: u64,
}

/// Run the sweep; returns all rows (also printed + written to CSV).
pub fn run(quick: bool) -> Vec<Row> {
    super::headline("Table 1: gradient-method complexity (replicated Example 1, d = 10)");
    let dim = 10;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(7);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let steps_sweep: &[usize] =
        if quick { &[64, 256, 1024] } else { &[64, 256, 1024, 4096, 16384] };
    let reps = if quick { 2 } else { 5 };

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        super::out_dir().join("table1_complexity.csv"),
        &["method", "steps", "seconds_amortized_batch", "memory_floats", "nfe"],
    )
    .expect("csv");

    println!(
        "{:<22} {:>7} {:>12} {:>14} {:>10}",
        "method", "L", "ms/run*", "mem (floats)", "NFE"
    );
    println!("(*amortized batch wall-clock per run — reps fan across threads)");
    // Every estimator runs through one problem definition; only the
    // SensAlg value (and the virtual-tree noise spec) changes. The reps
    // go through sensitivity_batch — the adjoint rows ride the batched
    // SoA kernel, the taped baselines its per-path fallback — so
    // reported time is amortized batch wall-clock per run (multi-path
    // throughput, the quantity a traffic-serving deployment cares
    // about). Per-path memory/NFE numbers are engine-independent
    // (bit-identical results; see tests/batch_engine.rs).
    for &steps in steps_sweep {
        let variants: Vec<(&'static str, SensAlg, NoiseMode)> = vec![
            ("forward_pathwise", SensAlg::ForwardPathwise, NoiseMode::StoredPath),
            (
                "backprop_solver",
                SensAlg::backprop(Method::MilsteinIto),
                NoiseMode::StoredPath,
            ),
            (
                "adjoint_stored_path",
                SensAlg::StochasticAdjoint(AdjointConfig::default()),
                NoiseMode::StoredPath,
            ),
            (
                "adjoint_virtual_tree",
                SensAlg::StochasticAdjoint(AdjointConfig::default()),
                NoiseMode::VirtualTree { tol: 0.1 / steps as f64 },
            ),
        ];
        for (name, alg, noise) in &variants {
            let base = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).noise(*noise);
            let problems: Vec<_> =
                (0..reps).map(|r| base.clone().key(key.fold_in(1000 + r as u64))).collect();
            let sw = Stopwatch::new();
            let outs =
                sensitivity_batch(&problems, alg, StepControl::Steps(steps), ExecConfig::default());
            let per_run = sw.elapsed_s() / reps as f64;
            let first = outs[0].as_ref().expect("algorithm validated for this SDE");
            let mem = first.stats.noise_memory;
            let nfe = first.stats.nfe();
            println!(
                "{:<22} {:>7} {:>12.3} {:>14} {:>10}",
                name,
                steps,
                per_run * 1e3,
                mem,
                nfe
            );
            csv.row(&[
                name.to_string(),
                steps.to_string(),
                format!("{per_run}"),
                mem.to_string(),
                nfe.to_string(),
            ])
            .ok();
            rows.push(Row { method: *name, steps, seconds: per_run, memory_floats: mem, nfe });
        }
    }
    csv.flush().ok();

    // Report empirical scaling exponents (fit log-log slope over the
    // sweep) so the table's O(·) claims are checkable at a glance.
    println!("\nempirical log-log slopes (time vs L | memory vs L):");
    for name in ["forward_pathwise", "backprop_solver", "adjoint_stored_path", "adjoint_virtual_tree"]
    {
        let pts: Vec<&Row> = rows.iter().filter(|r| r.method == name).collect();
        let slope = |f: &dyn Fn(&Row) -> f64| -> f64 {
            let n = pts.len() as f64;
            let xs: Vec<f64> = pts.iter().map(|r| (r.steps as f64).ln()).collect();
            let ys: Vec<f64> = pts.iter().map(|r| f(r).max(1e-12).ln()).collect();
            let mx = xs.iter().sum::<f64>() / n;
            let my = ys.iter().sum::<f64>() / n;
            let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
            let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
            num / den
        };
        println!(
            "  {:<22} time^{:.2}  mem^{:.2}",
            name,
            slope(&|r: &Row| r.seconds),
            slope(&|r: &Row| r.memory_floats as f64)
        );
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_has_expected_shape() {
        let rows = run(true);
        assert_eq!(rows.len(), 12); // 3 step counts × 4 methods

        let at = |m: &str, s: usize| rows.iter().find(|r| r.method == m && r.steps == s).unwrap();
        // Memory: tree is O(1) — flat across L; path/backprop grow.
        assert_eq!(
            at("adjoint_virtual_tree", 64).memory_floats,
            at("adjoint_virtual_tree", 1024).memory_floats
        );
        assert!(at("adjoint_stored_path", 1024).memory_floats > at("adjoint_stored_path", 64).memory_floats * 4);
        assert!(at("backprop_solver", 1024).memory_floats > at("backprop_solver", 64).memory_floats * 4);
        // Pathwise memory is O(1) in L (sensitivity matrix only + stored noise).
        let pw64 = at("forward_pathwise", 64).memory_floats;
        let pw1024 = at("forward_pathwise", 1024).memory_floats;
        // Only the stored-noise part grows.
        assert!(pw1024 < pw64 * 20);
        // Time: pathwise NFE carries the O(D) factor — with d=10 its
        // per-step cost is (1+d) eval-pairs vs the adjoint's 3 (one
        // forward + two backward-Heun), a ratio of ~3.7.
        assert!(at("forward_pathwise", 256).nfe > 3 * at("adjoint_stored_path", 256).nfe);
    }
}
