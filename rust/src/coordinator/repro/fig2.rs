//! Figure 2: backward simulation reconstructs the forward path in
//! Stratonovich form but not in Itô form.
//!
//! The harness runs GBM forward then backward with (a) Euler–Maruyama on
//! the raw Itô coefficients and (b) Heun on the converted Stratonovich
//! coefficients, over a step-size sweep, and writes both trajectories of
//! one illustrative path for plotting.

use crate::adjoint::reconstruct::reconstruction_experiment;
use crate::metrics::CsvWriter;
use crate::prng::PrngKey;
use crate::sde::problems::Example1;
use crate::sde::ReplicatedSde;
use crate::solvers::Method;

/// Result row: reconstruction errors at t0 for each scheme and step count.
#[derive(Clone, Copy, Debug)]
pub struct Row {
    pub steps: usize,
    pub ito_error: f64,
    pub strat_error: f64,
}

pub fn run(quick: bool) -> Vec<Row> {
    super::headline("Figure 2: backward path reconstruction, Itô vs Stratonovich");
    let sde = ReplicatedSde::new(Example1, 1);
    let theta = [1.0, 0.8];
    let z0 = [1.0];
    let key = PrngKey::from_seed(2);
    let steps_sweep: &[usize] = if quick { &[128, 1024] } else { &[128, 512, 2048, 8192] };

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        super::out_dir().join("fig2_reconstruction.csv"),
        &["steps", "ito_initial_error", "strat_initial_error"],
    )
    .expect("csv");
    println!("{:>8} {:>18} {:>18}", "L", "Itô |err(t0)|", "Strat |err(t0)|");
    for &steps in steps_sweep {
        let ito =
            reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, steps, key, Method::EulerMaruyama);
        let strat = reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, steps, key, Method::Heun);
        println!("{:>8} {:>18.6} {:>18.6}", steps, ito.initial_error, strat.initial_error);
        csv.row_f64(&[steps as f64, ito.initial_error, strat.initial_error]).ok();
        rows.push(Row { steps, ito_error: ito.initial_error, strat_error: strat.initial_error });
    }
    csv.flush().ok();

    // Trajectory dump for the figure itself (finest sweep entry).
    let steps = *steps_sweep.last().unwrap();
    let ito =
        reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, steps, key, Method::EulerMaruyama);
    let strat = reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, steps, key, Method::Heun);
    let mut traj = CsvWriter::create(
        super::out_dir().join("fig2_trajectories.csv"),
        &["t", "forward", "ito_backward", "strat_backward"],
    )
    .expect("csv");
    let stride = (steps / 256).max(1);
    for k in (0..ito.times.len()).step_by(stride) {
        traj.row_f64(&[ito.times[k], strat.forward[k], ito.backward[k], strat.backward[k]]).ok();
    }
    traj.flush().ok();
    println!("(one-path trajectories written to bench_out/fig2_trajectories.csv)");
    rows
}

#[cfg(test)]
mod tests {
    #[test]
    fn stratonovich_beats_ito_at_every_resolution() {
        let rows = super::run(true);
        for r in &rows {
            assert!(
                r.strat_error < r.ito_error,
                "at L={}: strat {} !< ito {}",
                r.steps,
                r.strat_error,
                r.ito_error
            );
        }
        // Stratonovich error must shrink with refinement; Itô's must not
        // vanish.
        assert!(rows.last().unwrap().strat_error < rows[0].strat_error);
        assert!(rows.last().unwrap().ito_error > 1e-3);
    }
}
