//! The latent-SDE trainer: minibatch Adam on the **batched SoA engine**,
//! with LR decay, KL annealing, validation, CSV/JSONL logging, and exact
//! resume from a [`TrainState`] checkpoint.
//!
//! Parallelism model: each iteration's minibatch of M sequences × S
//! posterior samples is one [`crate::latent::elbo_step_batch`] call — the
//! flattened path list is cut into chunks, each chunk advances all its
//! paths *together* through batched encoder/solver/adjoint kernels, and
//! chunks fan across a `std::thread::scope` pool (`tokio`/rayon are not
//! in the vendored crate set — DESIGN.md §3). Per-path keys are derived
//! as `key(iter).fold_in(seq_index).fold_in(sample)`, and the engine
//! reduces per-path gradients in path order, so the batch gradient is a
//! pure function of (params, minibatch, iter) — independent of worker
//! count and chunk layout, bit-identical to a sequential scalar
//! [`crate::latent::elbo_step`] loop (pinned by `tests/trainer_batch.rs`).
//! The scalar path remains in the tree as that oracle.
//!
//! The minibatch schedule, learning-rate decay, and KL annealing are pure
//! functions of the *absolute* iteration index, which is what makes
//! resumed runs bit-identical to uninterrupted ones.

use super::checkpoint::TrainState;
use super::config::TrainConfig;
use crate::data::TimeSeriesDataset;
use crate::latent::{elbo_step_batch, elbo_value_multi, ElboConfig, LatentSdeModel};
use crate::metrics::{CsvWriter, OnlineStats, Stopwatch};
use crate::optim::{clip_grad_norm, Adam, ExponentialDecay, KlAnneal};
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;

/// Per-iteration record.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    pub iter: u64,
    pub loss: f64,
    pub log_px: f64,
    pub kl_path: f64,
    pub kl_z0: f64,
    pub grad_norm: f64,
    pub seconds: f64,
}

/// Full training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub history: Vec<IterRecord>,
    pub val_history: Vec<(u64, EvalReport)>,
    pub final_params: Vec<f64>,
    /// Complete state (params + Adam moments + counters) at the end of
    /// the run — save with [`super::checkpoint::save_state`] to resume
    /// exactly via [`train_latent_sde_from`].
    pub final_state: TrainState,
    pub total_seconds: f64,
}

/// Evaluation metrics over a set of sequences.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalReport {
    pub loss: f64,
    pub recon_mse: f64,
    pub n_sequences: usize,
}

/// One minibatch gradient on the batched engine: sums over all
/// sequences × samples. Returns (grad_sum, loss_sum, logpx, klpath, klz0,
/// mse_sum) — the caller divides by `indices.len() * n_samples`.
/// Last-iteration phase timings as registry gauges (µs). Seconds→µs is
/// integer bookkeeping on already-computed wall times — the f64 training
/// path is untouched.
fn publish_train_gauges(iter_seconds: f64, grad_seconds: f64) {
    use std::sync::OnceLock;
    static ITER_US: OnceLock<crate::obs::Gauge> = OnceLock::new();
    static GRAD_US: OnceLock<crate::obs::Gauge> = OnceLock::new();
    ITER_US
        .get_or_init(|| crate::obs::gauge("train.iter_us"))
        .set((iter_seconds * 1e6) as u64);
    GRAD_US
        .get_or_init(|| crate::obs::gauge("train.grad_us"))
        .set((grad_seconds * 1e6) as u64);
}

#[allow(clippy::too_many_arguments)]
fn batch_gradients(
    model: &LatentSdeModel,
    params: &[f64],
    dataset: &TimeSeriesDataset,
    indices: &[usize],
    key: PrngKey,
    ecfg: &ElboConfig,
    n_samples: usize,
    n_workers: usize,
) -> (Vec<f64>, f64, f64, f64, f64, f64) {
    let obs: Vec<&[f64]> = indices.iter().map(|&s| dataset.series(s)).collect();
    let keys: Vec<PrngKey> = indices.iter().map(|&s| key.fold_in(s as u64)).collect();
    let out = elbo_step_batch(
        model,
        params,
        &dataset.times,
        &obs,
        &keys,
        ecfg,
        n_samples,
        n_workers,
    );
    (out.grad, out.loss, out.log_px, out.kl_path, out.kl_z0, out.recon_mse)
}

/// Evaluate mean loss / reconstruction MSE over sequences — values only,
/// `n_samples`-sample ELBO estimates on the batched multi-sample
/// estimator (no gradients are computed, unlike the pre-batched trainer
/// which ran the full adjoint and threw the gradient away).
pub fn evaluate(
    model: &LatentSdeModel,
    params: &[f64],
    dataset: &TimeSeriesDataset,
    indices: &[usize],
    key: PrngKey,
    ecfg: &ElboConfig,
    n_samples: usize,
) -> EvalReport {
    let mut loss = OnlineStats::new();
    let mut mse = OnlineStats::new();
    for &s in indices {
        let out = elbo_value_multi(
            model,
            params,
            &dataset.times,
            dataset.series(s),
            key.fold_in(s as u64),
            ecfg,
            n_samples.max(1),
        );
        loss.push(out.loss);
        mse.push(out.recon_mse);
    }
    EvalReport { loss: loss.mean(), recon_mse: mse.mean(), n_sequences: indices.len() }
}

/// The shuffled minibatches of one epoch — a pure function of
/// `(train_idx, batch_size, key, epoch)`, so resumed runs see the same
/// schedule (iteration `i` uses epoch `i / bpe`, slot `i % bpe`).
fn epoch_minibatches(
    dataset: &TimeSeriesDataset,
    train_idx: &[usize],
    batch_size: usize,
    key: PrngKey,
    epoch: u64,
) -> Vec<Vec<usize>> {
    dataset
        .minibatches(train_idx, batch_size, key.fold_in(1_000_000 + epoch), epoch)
        .into_iter()
        .map(|b| b.indices)
        .collect()
}

/// FNV-1a over everything that determines the training float stream:
/// seed, minibatch geometry, solver substeps, LR/KL schedules, sample
/// count, kernel tier, and the training indices. Stored in the [`TrainState`] so a
/// checkpoint refuses to resume under a different seed/config/dataset
/// split (which would silently void the bit-identical-resume contract).
/// Worker count is deliberately excluded — it never changes a float.
fn schedule_fingerprint(cfg: &TrainConfig, train_idx: &[usize]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let fields = [
        cfg.seed,
        cfg.batch_size as u64,
        cfg.substeps as u64,
        cfg.lr.to_bits(),
        cfg.lr_decay.to_bits(),
        cfg.kl_weight.to_bits(),
        cfg.kl_anneal_iters,
        cfg.grad_clip.to_bits(),
        cfg.elbo_samples.max(1) as u64,
        cfg.exec.tier as u64,
        train_idx.len() as u64,
    ];
    for v in fields.into_iter().chain(train_idx.iter().map(|&i| i as u64)) {
        h ^= v;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Train a latent SDE on `train_idx` of `dataset`; optionally log CSV to
/// `log_path` and validate on `val_idx`. Fresh run (see
/// [`train_latent_sde_from`] for resuming).
pub fn train_latent_sde(
    model: &LatentSdeModel,
    dataset: &TimeSeriesDataset,
    train_idx: &[usize],
    val_idx: &[usize],
    cfg: &TrainConfig,
    log_path: Option<&str>,
) -> TrainReport {
    train_latent_sde_from(model, dataset, train_idx, val_idx, cfg, log_path, None)
}

/// Train a latent SDE, optionally resuming from a [`TrainState`]. With
/// `resume` present, the run continues at `resume.iter` for `cfg.iters`
/// *additional* iterations and is bit-identical to an uninterrupted run
/// with the larger iteration budget (same seed / config), because the
/// minibatch schedule, LR decay, KL annealing, and per-path keys are all
/// pure functions of the absolute iteration, and the checkpoint carries
/// the Adam moments.
#[allow(clippy::too_many_arguments)]
pub fn train_latent_sde_from(
    model: &LatentSdeModel,
    dataset: &TimeSeriesDataset,
    train_idx: &[usize],
    val_idx: &[usize],
    cfg: &TrainConfig,
    log_path: Option<&str>,
    resume: Option<&TrainState>,
) -> TrainReport {
    let key = PrngKey::from_seed(cfg.seed);
    let (k_init, k_train) = key.split();
    let fingerprint = schedule_fingerprint(cfg, train_idx);
    let (mut params, mut adam, start_iter) = match resume {
        Some(st) => {
            assert_eq!(
                st.params.len(),
                model.n_params,
                "resume checkpoint does not match this model"
            );
            assert_eq!(
                st.fingerprint, fingerprint,
                "resume checkpoint was trained under a different \
                 seed/config/dataset split — continuing would silently break \
                 the exact-resume contract"
            );
            (
                st.params.clone(),
                Adam::from_state(cfg.lr, st.adam_m.clone(), st.adam_v.clone(), st.adam_t),
                st.iter,
            )
        }
        None => {
            let params = model.init_params(k_init);
            let adam = Adam::new(model.n_params, cfg.lr);
            (params, adam, 0)
        }
    };
    let decay = ExponentialDecay::new(cfg.lr_decay);
    let anneal = KlAnneal::new(cfg.kl_weight, cfg.kl_anneal_iters);
    let n_samples = cfg.elbo_samples.max(1);

    const LOG_HEADER: [&str; 7] =
        ["iter", "loss", "log_px", "kl_path", "kl_z0", "grad_norm", "seconds"];
    let mut log = log_path.map(|p| {
        // A resumed run appends so the earlier segment of the curve
        // survives; a fresh run truncates.
        if resume.is_some() {
            CsvWriter::append_or_create(p, &LOG_HEADER).expect("opening training log")
        } else {
            CsvWriter::create(p, &LOG_HEADER).expect("creating training log")
        }
    });

    let total = Stopwatch::new();
    let mut history = Vec::new();
    let mut val_history = Vec::new();
    let bpe = train_idx.len().div_ceil(cfg.batch_size.max(1)).max(1) as u64;
    let mut cur_epoch = u64::MAX;
    let mut epoch_batches: Vec<Vec<usize>> = Vec::new();

    for iter in start_iter..start_iter + cfg.iters {
        let span_iter = crate::obs::span!("train.iter");
        let sw = Stopwatch::new();
        let epoch = iter / bpe;
        if epoch != cur_epoch {
            epoch_batches =
                epoch_minibatches(dataset, train_idx, cfg.batch_size, k_train, epoch);
            cur_epoch = epoch;
        }
        let batch = epoch_batches[(iter % bpe) as usize].clone();
        let beta = anneal.weight(iter);
        let ecfg = ElboConfig { substeps: cfg.substeps, kl_weight: beta, exec: cfg.exec };
        let span_grad = crate::obs::span!("train.grad");
        let grad_sw = Stopwatch::new();
        let (mut grad, loss, lpx, klp, klz, _mse) = batch_gradients(
            model,
            &params,
            dataset,
            &batch,
            k_train.fold_in(iter),
            &ecfg,
            n_samples,
            cfg.n_workers(),
        );
        let grad_seconds = grad_sw.elapsed_s();
        drop(span_grad);
        let span_optim = crate::obs::span!("train.optim");
        let inv = 1.0 / (batch.len() * n_samples) as f64;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        let grad_norm = clip_grad_norm(&mut grad, cfg.grad_clip);
        adam.step(&mut params, &grad, decay.scale(iter));
        drop(span_optim);

        let rec = IterRecord {
            iter,
            loss: loss * inv,
            log_px: lpx * inv,
            kl_path: klp * inv,
            kl_z0: klz * inv,
            grad_norm,
            seconds: sw.elapsed_s(),
        };
        if let Some(w) = log.as_mut() {
            w.row_f64(&[
                rec.iter as f64,
                rec.loss,
                rec.log_px,
                rec.kl_path,
                rec.kl_z0,
                rec.grad_norm,
                rec.seconds,
            ])
            .ok();
        }
        history.push(rec);
        // Per-iteration phase breakdown as registry gauges (µs, last
        // iteration wins): together with the train.iter / train.grad /
        // train.optim spans this answers "where does a step spend time".
        publish_train_gauges(sw.elapsed_s(), grad_seconds);

        if cfg.val_every > 0 && !val_idx.is_empty() && (iter + 1) % cfg.val_every == 0 {
            let _span_val = crate::obs::span!("train.validate");
            let ecfg_val = ElboConfig {
                substeps: cfg.substeps,
                kl_weight: cfg.kl_weight,
                exec: cfg.exec,
            };
            let k_val = k_train.fold_in(u64::MAX - iter);
            let report =
                evaluate(model, &params, dataset, val_idx, k_val, &ecfg_val, n_samples);
            val_history.push((iter, report));
        }
        drop(span_iter);
    }
    if let Some(w) = log.as_mut() {
        w.flush().ok();
    }

    let (m, v, t) = adam.state();
    let final_state = TrainState {
        params: params.clone(),
        adam_m: m.to_vec(),
        adam_v: v.to_vec(),
        adam_t: t,
        iter: start_iter + cfg.iters,
        fingerprint,
    };
    TrainReport {
        history,
        val_history,
        final_params: params,
        final_state,
        total_seconds: total.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gbm::{generate, GbmConfig};
    use crate::latent::{LatentSdeConfig, LatentSdeModel};

    fn tiny_setup() -> (LatentSdeModel, TimeSeriesDataset) {
        let model = LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 1,
            latent_dim: 2,
            context_dim: 1,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 8,
            obs_noise_std: 0.05,
            ..Default::default()
        });
        let ds = generate(
            PrngKey::from_seed(1),
            &GbmConfig { n_series: 8, dt_obs: 0.1, ..Default::default() },
        );
        (model, ds)
    }

    #[test]
    fn training_loop_reduces_loss() {
        let (model, ds) = tiny_setup();
        let idx: Vec<usize> = (0..8).collect();
        let cfg = TrainConfig {
            iters: 25,
            batch_size: 4,
            lr: 5e-3,
            substeps: 3,
            kl_weight: 0.1,
            kl_anneal_iters: 5,
            exec: ExecConfig::new().threads(2),
            val_every: 0,
            ..Default::default()
        };
        let report = train_latent_sde(&model, &ds, &idx, &[], &cfg, None);
        assert_eq!(report.history.len(), 25);
        let first: f64 =
            report.history[..5].iter().map(|r| r.loss).sum::<f64>() / 5.0;
        let last: f64 =
            report.history[20..].iter().map(|r| r.loss).sum::<f64>() / 5.0;
        assert!(
            last < first,
            "training loss did not improve: first5 {first:.2} last5 {last:.2}"
        );
        assert!(report.final_params.iter().all(|p| p.is_finite()));
        assert_eq!(report.final_state.iter, 25);
        assert_eq!(report.final_state.adam_t, 25);
    }

    #[test]
    fn batch_gradient_is_worker_count_independent_exactly() {
        // Determinism + correctness of the chunked batched engine: the
        // minibatch gradient must be the same float for any worker count.
        let (model, ds) = tiny_setup();
        let params = model.init_params(PrngKey::from_seed(2));
        let idx: Vec<usize> = (0..6).collect();
        let ecfg = ElboConfig { substeps: 3, kl_weight: 0.5, ..ElboConfig::default() };
        let key = PrngKey::from_seed(3);
        let (g1, l1, ..) = batch_gradients(&model, &params, &ds, &idx, key, &ecfg, 2, 1);
        let (g4, l4, ..) = batch_gradients(&model, &params, &ds, &idx, key, &ecfg, 2, 4);
        assert_eq!(l1, l4, "losses differ across worker counts");
        assert_eq!(g1, g4, "gradient differs across worker counts");
    }

    #[test]
    fn validation_history_recorded() {
        let (model, ds) = tiny_setup();
        let idx: Vec<usize> = (0..6).collect();
        let val: Vec<usize> = vec![6, 7];
        let cfg = TrainConfig {
            iters: 10,
            batch_size: 3,
            substeps: 2,
            val_every: 5,
            exec: ExecConfig::new().threads(2),
            ..Default::default()
        };
        let report = train_latent_sde(&model, &ds, &idx, &val, &cfg, None);
        assert_eq!(report.val_history.len(), 2);
        assert!(report.val_history[0].1.n_sequences == 2);
    }

    /// Interrupt + resume must be bit-identical to the uninterrupted run:
    /// the checkpoint carries the Adam moments and the absolute iteration
    /// drives every schedule.
    #[test]
    fn resumed_training_is_bit_identical() {
        let (model, ds) = tiny_setup();
        let idx: Vec<usize> = (0..8).collect();
        let base = TrainConfig {
            iters: 8,
            batch_size: 3,
            lr: 4e-3,
            substeps: 2,
            kl_weight: 0.2,
            kl_anneal_iters: 6,
            exec: ExecConfig::new().threads(2),
            val_every: 0,
            ..Default::default()
        };
        let full = train_latent_sde(&model, &ds, &idx, &[], &base, None);

        let head_cfg = TrainConfig { iters: 3, ..base };
        let head = train_latent_sde(&model, &ds, &idx, &[], &head_cfg, None);
        let tail_cfg = TrainConfig { iters: 5, ..base };
        let tail = train_latent_sde_from(
            &model,
            &ds,
            &idx,
            &[],
            &tail_cfg,
            None,
            Some(&head.final_state),
        );
        assert_eq!(tail.final_params, full.final_params, "resume diverged");
        assert_eq!(tail.final_state.adam_t, full.final_state.adam_t);
        assert_eq!(
            tail.history.iter().map(|r| r.loss).collect::<Vec<_>>(),
            full.history[3..].iter().map(|r| r.loss).collect::<Vec<_>>(),
        );
    }
}
