//! The latent-SDE trainer: minibatch Adam with data-parallel gradient
//! averaging across a thread pool, LR decay, KL annealing, validation,
//! and CSV/JSONL logging.
//!
//! Parallelism model: each worker thread takes one sequence of the
//! minibatch at a time from a shared index, computes a full
//! [`crate::latent::elbo_step`] (forward SDE solve + stochastic adjoint +
//! encoder/decoder backprop), and the coordinator averages the per-worker
//! gradient sums (a tree reduction is unnecessary at ≤8 workers; a flat
//! sum is exact and deterministic given the per-sequence keys). `tokio`
//! is not in the vendored crate set, so the pool is `std::thread::scope`
//! (DESIGN.md §3) — the workload is pure CPU compute, not I/O.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::config::TrainConfig;
use crate::data::TimeSeriesDataset;
use crate::latent::{elbo_step, ElboConfig, LatentSdeModel};
use crate::metrics::{CsvWriter, OnlineStats, Stopwatch};
use crate::optim::{clip_grad_norm, Adam, ExponentialDecay, KlAnneal};
use crate::prng::PrngKey;

/// Per-iteration record.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    pub iter: u64,
    pub loss: f64,
    pub log_px: f64,
    pub kl_path: f64,
    pub kl_z0: f64,
    pub grad_norm: f64,
    pub seconds: f64,
}

/// Full training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub history: Vec<IterRecord>,
    pub val_history: Vec<(u64, EvalReport)>,
    pub final_params: Vec<f64>,
    pub total_seconds: f64,
}

/// Evaluation metrics over a set of sequences.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalReport {
    pub loss: f64,
    pub recon_mse: f64,
    pub n_sequences: usize,
}

/// Sum ELBO gradients over `indices` of `dataset` using `n_workers`
/// threads. Returns (grad_sum, loss_sum, logpx, klpath, klz0, mse_sum).
#[allow(clippy::too_many_arguments)]
fn batch_gradients(
    model: &LatentSdeModel,
    params: &[f64],
    dataset: &TimeSeriesDataset,
    indices: &[usize],
    key: PrngKey,
    ecfg: &ElboConfig,
    n_workers: usize,
) -> (Vec<f64>, f64, f64, f64, f64, f64) {
    let n = indices.len();
    let next = AtomicUsize::new(0);
    let workers = n_workers.clamp(1, n.max(1));

    let results: Vec<(Vec<f64>, f64, f64, f64, f64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                scope.spawn(move || {
                    let mut grad = vec![0.0; model.n_params];
                    let (mut loss, mut lpx, mut klp, mut klz, mut mse) =
                        (0.0, 0.0, 0.0, 0.0, 0.0);
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let s = indices[i];
                        let out = elbo_step(
                            model,
                            params,
                            &dataset.times,
                            dataset.series(s),
                            key.fold_in(s as u64),
                            ecfg,
                        );
                        for (g, og) in grad.iter_mut().zip(&out.grad) {
                            *g += og;
                        }
                        loss += out.loss;
                        lpx += out.log_px;
                        klp += out.kl_path;
                        klz += out.kl_z0;
                        mse += out.recon_mse;
                    }
                    (grad, loss, lpx, klp, klz, mse)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let mut grad = vec![0.0; model.n_params];
    let (mut loss, mut lpx, mut klp, mut klz, mut mse) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for (g, l, a, b, c, m) in results {
        for (gi, gv) in grad.iter_mut().zip(&g) {
            *gi += gv;
        }
        loss += l;
        lpx += a;
        klp += b;
        klz += c;
        mse += m;
    }
    (grad, loss, lpx, klp, klz, mse)
}

/// Evaluate mean loss / reconstruction MSE over sequences (no gradients —
/// uses `elbo_step` and discards the gradient; the forward pass dominates
/// anyway at small substeps).
pub fn evaluate(
    model: &LatentSdeModel,
    params: &[f64],
    dataset: &TimeSeriesDataset,
    indices: &[usize],
    key: PrngKey,
    ecfg: &ElboConfig,
) -> EvalReport {
    let mut loss = OnlineStats::new();
    let mut mse = OnlineStats::new();
    for &s in indices {
        let out = elbo_step(model, params, &dataset.times, dataset.series(s), key.fold_in(s as u64), ecfg);
        loss.push(out.loss);
        mse.push(out.recon_mse);
    }
    EvalReport { loss: loss.mean(), recon_mse: mse.mean(), n_sequences: indices.len() }
}

/// Train a latent SDE on `train_idx` of `dataset`; optionally log CSV to
/// `log_path` and validate on `val_idx`.
pub fn train_latent_sde(
    model: &LatentSdeModel,
    dataset: &TimeSeriesDataset,
    train_idx: &[usize],
    val_idx: &[usize],
    cfg: &TrainConfig,
    log_path: Option<&str>,
) -> TrainReport {
    let key = PrngKey::from_seed(cfg.seed);
    let (k_init, k_train) = key.split();
    let mut params = model.init_params(k_init);
    let mut adam = Adam::new(params.len(), cfg.lr);
    let decay = ExponentialDecay::new(cfg.lr_decay);
    let anneal = KlAnneal::new(cfg.kl_weight, cfg.kl_anneal_iters);

    let mut log = log_path.map(|p| {
        CsvWriter::create(
            p,
            &["iter", "loss", "log_px", "kl_path", "kl_z0", "grad_norm", "seconds"],
        )
        .expect("creating training log")
    });

    let total = Stopwatch::new();
    let mut history = Vec::new();
    let mut val_history = Vec::new();
    let epochs_needed = (cfg.iters as usize * cfg.batch_size).div_ceil(train_idx.len().max(1));
    let mut batches: Vec<Vec<usize>> = Vec::new();
    for e in 0..=epochs_needed as u64 {
        for b in dataset.minibatches(train_idx, cfg.batch_size, k_train.fold_in(1_000_000 + e), e)
        {
            batches.push(b.indices);
        }
    }

    for iter in 0..cfg.iters {
        let sw = Stopwatch::new();
        let batch = &batches[iter as usize % batches.len()];
        let beta = anneal.weight(iter);
        let ecfg = ElboConfig { substeps: cfg.substeps, kl_weight: beta };
        let (mut grad, loss, lpx, klp, klz, _mse) = batch_gradients(
            model,
            &params,
            dataset,
            batch,
            k_train.fold_in(iter),
            &ecfg,
            cfg.n_workers,
        );
        let inv = 1.0 / batch.len() as f64;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        let grad_norm = clip_grad_norm(&mut grad, cfg.grad_clip);
        adam.step(&mut params, &grad, decay.scale(iter));

        let rec = IterRecord {
            iter,
            loss: loss * inv,
            log_px: lpx * inv,
            kl_path: klp * inv,
            kl_z0: klz * inv,
            grad_norm,
            seconds: sw.elapsed_s(),
        };
        if let Some(w) = log.as_mut() {
            w.row_f64(&[
                rec.iter as f64,
                rec.loss,
                rec.log_px,
                rec.kl_path,
                rec.kl_z0,
                rec.grad_norm,
                rec.seconds,
            ])
            .ok();
        }
        history.push(rec);

        if cfg.val_every > 0 && !val_idx.is_empty() && (iter + 1) % cfg.val_every == 0 {
            let ecfg_val = ElboConfig { substeps: cfg.substeps, kl_weight: cfg.kl_weight };
            let report =
                evaluate(model, &params, dataset, val_idx, k_train.fold_in(u64::MAX - iter), &ecfg_val);
            val_history.push((iter, report));
        }
    }
    if let Some(w) = log.as_mut() {
        w.flush().ok();
    }

    TrainReport { history, val_history, final_params: params, total_seconds: total.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gbm::{generate, GbmConfig};
    use crate::latent::{LatentSdeConfig, LatentSdeModel};

    fn tiny_setup() -> (LatentSdeModel, TimeSeriesDataset) {
        let model = LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 1,
            latent_dim: 2,
            context_dim: 1,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 8,
            obs_noise_std: 0.05,
            ..Default::default()
        });
        let ds = generate(
            PrngKey::from_seed(1),
            &GbmConfig { n_series: 8, dt_obs: 0.1, ..Default::default() },
        );
        (model, ds)
    }

    #[test]
    fn training_loop_reduces_loss() {
        let (model, ds) = tiny_setup();
        let idx: Vec<usize> = (0..8).collect();
        let cfg = TrainConfig {
            iters: 25,
            batch_size: 4,
            lr: 5e-3,
            substeps: 3,
            kl_weight: 0.1,
            kl_anneal_iters: 5,
            n_workers: 2,
            val_every: 0,
            ..Default::default()
        };
        let report = train_latent_sde(&model, &ds, &idx, &[], &cfg, None);
        assert_eq!(report.history.len(), 25);
        let first: f64 =
            report.history[..5].iter().map(|r| r.loss).sum::<f64>() / 5.0;
        let last: f64 =
            report.history[20..].iter().map(|r| r.loss).sum::<f64>() / 5.0;
        assert!(
            last < first,
            "training loss did not improve: first5 {first:.2} last5 {last:.2}"
        );
        assert!(report.final_params.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn parallel_gradients_match_serial() {
        // Determinism + correctness of the worker pool: the batch gradient
        // must not depend on the worker count.
        let (model, ds) = tiny_setup();
        let params = model.init_params(PrngKey::from_seed(2));
        let idx: Vec<usize> = (0..6).collect();
        let ecfg = ElboConfig { substeps: 3, kl_weight: 0.5 };
        let key = PrngKey::from_seed(3);
        let (g1, l1, ..) = batch_gradients(&model, &params, &ds, &idx, key, &ecfg, 1);
        let (g4, l4, ..) = batch_gradients(&model, &params, &ds, &idx, key, &ecfg, 4);
        assert!((l1 - l4).abs() < 1e-9, "losses differ: {l1} vs {l4}");
        for (a, b) in g1.iter().zip(&g4) {
            assert!((a - b).abs() < 1e-9, "gradient differs across worker counts");
        }
    }

    #[test]
    fn validation_history_recorded() {
        let (model, ds) = tiny_setup();
        let idx: Vec<usize> = (0..6).collect();
        let val: Vec<usize> = vec![6, 7];
        let cfg = TrainConfig {
            iters: 10,
            batch_size: 3,
            substeps: 2,
            val_every: 5,
            n_workers: 2,
            ..Default::default()
        };
        let report = train_latent_sde(&model, &ds, &idx, &val, &cfg, None);
        assert_eq!(report.val_history.len(), 2);
        assert!(report.val_history[0].1.n_sequences == 2);
    }
}
