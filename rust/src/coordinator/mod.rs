//! L3 training coordinator: experiment configs, the multi-worker trainer,
//! checkpointing (whose typed-error load path also feeds the
//! [`crate::serve`] registry at `sdegrad serve` startup), the
//! reproduction harnesses for every table and figure in the paper
//! (shared by `cargo bench` targets and the `sdegrad repro` CLI), and
//! the [`bench`] harnesses (`sdegrad bench throughput|serve` →
//! `BENCH_*.json`, gated by `sdegrad bench compare`).

pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod repro;
pub mod trainer;

pub use checkpoint::{
    load_any_params, load_params, load_state, save_params, save_state, TrainState,
};
pub use config::TrainConfig;
pub use trainer::{train_latent_sde, train_latent_sde_from, EvalReport, TrainReport};
