//! Checkpoints: little-endian f64 with a small header.
//!
//! Two formats:
//! * `SDEGRAD1` — a bare flat parameter vector ([`save_params`] /
//!   [`load_params`]): enough for inference/evaluation.
//! * `SDEGRAD2` — the full [`TrainState`] ([`save_state`] /
//!   [`load_state`]): parameters **plus the Adam moments, Adam step
//!   count, and the next training iteration**, so a resumed run takes
//!   bit-identical optimizer steps to the uninterrupted one (pinned by
//!   the trainer's resume test). Checkpointing only the parameters resets
//!   the Adam moments to zero on resume, which visibly kinks the loss
//!   curve — the bug this format fixes.

use std::io::Write;
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

const MAGIC: &[u8; 8] = b"SDEGRAD1";
const MAGIC_STATE: &[u8; 8] = b"SDEGRAD2";

/// Everything a training run needs to continue exactly: parameters, Adam
/// first/second moments, the Adam step counter, and the next iteration
/// index (which also drives the minibatch schedule, LR decay, and KL
/// annealing — all pure functions of the absolute iteration).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub params: Vec<f64>,
    pub adam_m: Vec<f64>,
    pub adam_v: Vec<f64>,
    pub adam_t: u64,
    /// Next training iteration (0-based; a run that finished iterations
    /// `0..n` stores `n`).
    pub iter: u64,
    /// Hash of everything that determines the training float stream
    /// (seed, batch size, substeps, LR schedule, KL schedule, sample
    /// count, train indices — see the trainer's `schedule_fingerprint`).
    /// Resuming checks it so a checkpoint cannot silently continue under
    /// a different seed/config/dataset, which would break the
    /// bit-identical-resume contract without any visible error.
    pub fingerprint: u64,
}

fn write_f64s(f: &mut impl Write, xs: &[f64]) -> Result<()> {
    for v in xs {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Cursor over a fully-read checkpoint file. Every read is
/// bounds-checked against the *actual* file length and returns a typed
/// error on short files — a truncated or corrupt checkpoint must surface
/// as a clean `Err` (e.g. at `sdegrad serve` startup), never as a panic
/// or an attempted huge allocation from a garbage length header.
struct Cursor<'b> {
    buf: &'b [u8],
    pos: usize,
}

impl<'b> Cursor<'b> {
    fn new(buf: &'b [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'b [u8]> {
        let left = self.buf.len() - self.pos;
        if left < n {
            bail!("truncated checkpoint: {what} needs {n} bytes, {left} left");
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let raw = self.take(8, what)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(raw);
        Ok(u64::from_le_bytes(b))
    }

    /// Read a `u64` element count and validate it against the bytes that
    /// are actually left, so a garbage header cannot drive a huge
    /// allocation.
    fn len_header(&mut self, what: &str) -> Result<usize> {
        let n = self.u64(what)?;
        let left = (self.buf.len() - self.pos) as u64;
        if n.checked_mul(8).map(|bytes| bytes > left).unwrap_or(true) {
            bail!(
                "corrupt checkpoint: {what} claims {n} f64s but only {left} bytes remain"
            );
        }
        Ok(n as usize)
    }

    fn f64s(&mut self, n: usize, what: &str) -> Result<Vec<f64>> {
        let raw = self.take(n * 8, what)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                let mut b = [0u8; 8];
                b.copy_from_slice(c);
                f64::from_le_bytes(b)
            })
            .collect())
    }

    fn finish(&self) -> Result<()> {
        let left = self.buf.len() - self.pos;
        if left != 0 {
            bail!("corrupt checkpoint: {left} unexpected trailing bytes");
        }
        Ok(())
    }
}

fn read_file<P: AsRef<Path>>(path: P) -> Result<Vec<u8>> {
    std::fs::read(&path).with_context(|| format!("reading {:?}", path.as_ref()))
}

/// Save a flat parameter vector.
pub fn save_params<P: AsRef<Path>>(path: P, params: &[f64]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    write_f64s(&mut f, params)
}

/// Load a flat parameter vector.
pub fn load_params<P: AsRef<Path>>(path: P) -> Result<Vec<f64>> {
    parse_params(&read_file(path)?)
}

fn parse_params(buf: &[u8]) -> Result<Vec<f64>> {
    let mut c = Cursor::new(buf);
    if c.take(8, "magic")? != MAGIC {
        bail!("not an sdegrad checkpoint (bad magic)");
    }
    let n = c.len_header("parameter count")?;
    let params = c.f64s(n, "parameters")?;
    c.finish()?;
    Ok(params)
}

/// Save a full training state (params + optimizer moments + counters).
pub fn save_state<P: AsRef<Path>>(path: P, state: &TrainState) -> Result<()> {
    if state.params.len() != state.adam_m.len() || state.params.len() != state.adam_v.len() {
        bail!(
            "inconsistent TrainState: {} params vs {}/{} moments",
            state.params.len(),
            state.adam_m.len(),
            state.adam_v.len()
        );
    }
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC_STATE)?;
    f.write_all(&state.iter.to_le_bytes())?;
    f.write_all(&state.adam_t.to_le_bytes())?;
    f.write_all(&state.fingerprint.to_le_bytes())?;
    f.write_all(&(state.params.len() as u64).to_le_bytes())?;
    write_f64s(&mut f, &state.params)?;
    write_f64s(&mut f, &state.adam_m)?;
    write_f64s(&mut f, &state.adam_v)
}

/// Load a full training state.
pub fn load_state<P: AsRef<Path>>(path: P) -> Result<TrainState> {
    parse_state(&read_file(path)?)
}

fn parse_state(buf: &[u8]) -> Result<TrainState> {
    let mut c = Cursor::new(buf);
    if c.take(8, "magic")? != MAGIC_STATE {
        bail!("not an sdegrad training-state checkpoint (bad magic)");
    }
    let iter = c.u64("iteration counter")?;
    let adam_t = c.u64("Adam step counter")?;
    let fingerprint = c.u64("schedule fingerprint")?;
    let n = c.u64("parameter count")? as usize;
    // Three n-long vectors follow; validate the claimed count against the
    // actual remaining bytes before allocating anything.
    let left = buf.len() - 40;
    if (n as u64).checked_mul(24).map(|b| b as usize != left).unwrap_or(true) {
        bail!(
            "corrupt training-state checkpoint: {n} params need {} bytes of \
             vectors, file has {left}",
            n.saturating_mul(24)
        );
    }
    let params = c.f64s(n, "parameters")?;
    let adam_m = c.f64s(n, "Adam first moments")?;
    let adam_v = c.f64s(n, "Adam second moments")?;
    c.finish()?;
    Ok(TrainState { params, adam_m, adam_v, adam_t, iter, fingerprint })
}

/// Load the parameter vector from *either* checkpoint format, dispatching
/// on the magic: `SDEGRAD1` (bare params) or `SDEGRAD2` (full
/// [`TrainState`], whose params are returned). This is what inference
/// consumers (`sdegrad serve`) use, so a model can be served from
/// whichever file a training run left behind. One read; the parse runs
/// over the in-memory buffer.
pub fn load_any_params<P: AsRef<Path>>(path: P) -> Result<Vec<f64>> {
    let buf = read_file(&path)?;
    match buf.get(..8) {
        Some(m) if m == MAGIC => parse_params(&buf),
        Some(m) if m == MAGIC_STATE => Ok(parse_state(&buf)?.params),
        Some(_) => bail!("not an sdegrad checkpoint (bad magic)"),
        None => bail!("truncated checkpoint: shorter than the 8-byte magic"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test");
        let path = dir.join("p.bin");
        let params = vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE, 1e300];
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(params, loaded);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
        assert!(load_state(&path).is_err());
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test3");
        let path = dir.join("state.bin");
        let state = TrainState {
            params: vec![1.5, -2.25, 1e-300],
            adam_m: vec![0.125, -3.5, 0.0],
            adam_v: vec![4.0, 5e-5, 1e300],
            adam_t: 77,
            iter: 42,
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
        };
        save_state(&path, &state).unwrap();
        let loaded = load_state(&path).unwrap();
        assert_eq!(state, loaded);
    }

    #[test]
    fn formats_are_not_confusable() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test4");
        let p_params = dir.join("params.bin");
        let p_state = dir.join("state.bin");
        save_params(&p_params, &[1.0, 2.0]).unwrap();
        let state = TrainState {
            params: vec![1.0],
            adam_m: vec![0.0],
            adam_v: vec![0.0],
            adam_t: 1,
            iter: 1,
            fingerprint: 7,
        };
        save_state(&p_state, &state).unwrap();
        assert!(load_state(&p_params).is_err(), "params file read as state");
        assert!(load_params(&p_state).is_err(), "state file read as params");
    }

    /// Truncated files must surface as clean typed errors mentioning the
    /// truncation — the `sdegrad serve` startup path reports these
    /// instead of panicking.
    #[test]
    fn truncated_files_error_cleanly() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_trunc");
        let p_state = dir.join("state.bin");
        let state = TrainState {
            params: vec![1.0, 2.0, 3.0],
            adam_m: vec![0.1, 0.2, 0.3],
            adam_v: vec![1.0, 1.0, 1.0],
            adam_t: 5,
            iter: 5,
            fingerprint: 9,
        };
        save_state(&p_state, &state).unwrap();
        let full = std::fs::read(&p_state).unwrap();
        // Cut the file at several depths: inside the header, inside the
        // params block, and one byte short of complete.
        for cut in [4, 20, 48, full.len() - 1] {
            let p_cut = dir.join(format!("cut{cut}.bin"));
            std::fs::write(&p_cut, &full[..cut]).unwrap();
            let err = load_state(&p_cut).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("corrupt"),
                "cut at {cut}: unhelpful error {err:?}"
            );
        }
        // Same for the bare-params format.
        let p_params = dir.join("params.bin");
        save_params(&p_params, &[1.0, 2.0]).unwrap();
        let full = std::fs::read(&p_params).unwrap();
        let p_cut = dir.join("params_cut.bin");
        std::fs::write(&p_cut, &full[..full.len() - 3]).unwrap();
        let err = load_params(&p_cut).unwrap_err().to_string();
        assert!(err.contains("corrupt") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn wrong_magic_is_reported_as_bad_magic() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_magic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("future.bin");
        let mut bytes = b"SDEGRAD9".to_vec();
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&1.5f64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        for err in [
            load_params(&p).unwrap_err(),
            load_state(&p).unwrap_err(),
            load_any_params(&p).unwrap_err(),
        ] {
            assert!(err.to_string().contains("bad magic"), "{err}");
        }
    }

    /// A garbage length header must be rejected by comparing against the
    /// actual file size — not answered with a huge allocation.
    #[test]
    fn absurd_length_header_is_rejected_without_allocating() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_len");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("huge.bin");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_params(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");

        let p2 = dir.join("huge_state.bin");
        let mut bytes = MAGIC_STATE.to_vec();
        bytes.extend_from_slice(&[0u8; 24]); // iter, adam_t, fingerprint
        bytes.extend_from_slice(&(u64::MAX / 8).to_le_bytes());
        std::fs::write(&p2, &bytes).unwrap();
        let err = load_state(&p2).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn load_any_params_reads_both_formats() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_any");
        let p_params = dir.join("params.bin");
        let p_state = dir.join("state.bin");
        save_params(&p_params, &[1.5, -2.0]).unwrap();
        let state = TrainState {
            params: vec![3.25, 4.5],
            adam_m: vec![0.0; 2],
            adam_v: vec![0.0; 2],
            adam_t: 1,
            iter: 1,
            fingerprint: 0,
        };
        save_state(&p_state, &state).unwrap();
        assert_eq!(load_any_params(&p_params).unwrap(), vec![1.5, -2.0]);
        assert_eq!(load_any_params(&p_state).unwrap(), vec![3.25, 4.5]);
    }

    /// Adam resumed from a saved state takes bit-identical steps —
    /// "training resumes exactly" at the optimizer level (the trainer-level
    /// pin lives in tests/trainer_batch.rs).
    #[test]
    fn optimizer_resume_via_state_is_exact() {
        use crate::optim::Adam;
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test5");
        let path = dir.join("resume.bin");
        let g = |i: u64| vec![(i as f64).sin(), (i as f64 * 0.5).cos(), -0.3];

        let mut full = Adam::new(3, 0.02);
        let mut p_full = vec![0.1, 0.2, 0.3];
        for i in 0..12 {
            full.step(&mut p_full, &g(i), 1.0);
        }

        let mut head = Adam::new(3, 0.02);
        let mut p_head = vec![0.1, 0.2, 0.3];
        for i in 0..6 {
            head.step(&mut p_head, &g(i), 1.0);
        }
        let (m, v, t) = head.state();
        save_state(
            &path,
            &TrainState {
                params: p_head.clone(),
                adam_m: m.to_vec(),
                adam_v: v.to_vec(),
                adam_t: t,
                iter: 6,
                fingerprint: 0,
            },
        )
        .unwrap();

        let st = load_state(&path).unwrap();
        let mut tail = Adam::from_state(0.02, st.adam_m, st.adam_v, st.adam_t);
        let mut p = st.params;
        for i in st.iter..12 {
            tail.step(&mut p, &g(i), 1.0);
        }
        assert_eq!(p, p_full, "resumed run diverged from uninterrupted run");
    }
}
