//! Checkpoints: little-endian f64 with a small header.
//!
//! Two formats:
//! * `SDEGRAD1` — a bare flat parameter vector ([`save_params`] /
//!   [`load_params`]): enough for inference/evaluation.
//! * `SDEGRAD2` — the full [`TrainState`] ([`save_state`] /
//!   [`load_state`]): parameters **plus the Adam moments, Adam step
//!   count, and the next training iteration**, so a resumed run takes
//!   bit-identical optimizer steps to the uninterrupted one (pinned by
//!   the trainer's resume test). Checkpointing only the parameters resets
//!   the Adam moments to zero on resume, which visibly kinks the loss
//!   curve — the bug this format fixes.

use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

const MAGIC: &[u8; 8] = b"SDEGRAD1";
const MAGIC_STATE: &[u8; 8] = b"SDEGRAD2";

/// Everything a training run needs to continue exactly: parameters, Adam
/// first/second moments, the Adam step counter, and the next iteration
/// index (which also drives the minibatch schedule, LR decay, and KL
/// annealing — all pure functions of the absolute iteration).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub params: Vec<f64>,
    pub adam_m: Vec<f64>,
    pub adam_v: Vec<f64>,
    pub adam_t: u64,
    /// Next training iteration (0-based; a run that finished iterations
    /// `0..n` stores `n`).
    pub iter: u64,
    /// Hash of everything that determines the training float stream
    /// (seed, batch size, substeps, LR schedule, KL schedule, sample
    /// count, train indices — see the trainer's `schedule_fingerprint`).
    /// Resuming checks it so a checkpoint cannot silently continue under
    /// a different seed/config/dataset, which would break the
    /// bit-identical-resume contract without any visible error.
    pub fingerprint: u64,
}

fn write_f64s(f: &mut impl Write, xs: &[f64]) -> Result<()> {
    for v in xs {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s(f: &mut impl Read, n: usize) -> Result<Vec<f64>> {
    let mut buf = vec![0u8; n * 8];
    f.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Save a flat parameter vector.
pub fn save_params<P: AsRef<Path>>(path: P, params: &[f64]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    write_f64s(&mut f, params)
}

/// Load a flat parameter vector.
pub fn load_params<P: AsRef<Path>>(path: P) -> Result<Vec<f64>> {
    let mut f =
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an sdegrad checkpoint (bad magic)");
    }
    let n = read_u64(&mut f)? as usize;
    read_f64s(&mut f, n)
}

/// Save a full training state (params + optimizer moments + counters).
pub fn save_state<P: AsRef<Path>>(path: P, state: &TrainState) -> Result<()> {
    if state.params.len() != state.adam_m.len() || state.params.len() != state.adam_v.len() {
        bail!(
            "inconsistent TrainState: {} params vs {}/{} moments",
            state.params.len(),
            state.adam_m.len(),
            state.adam_v.len()
        );
    }
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC_STATE)?;
    f.write_all(&state.iter.to_le_bytes())?;
    f.write_all(&state.adam_t.to_le_bytes())?;
    f.write_all(&state.fingerprint.to_le_bytes())?;
    f.write_all(&(state.params.len() as u64).to_le_bytes())?;
    write_f64s(&mut f, &state.params)?;
    write_f64s(&mut f, &state.adam_m)?;
    write_f64s(&mut f, &state.adam_v)
}

/// Load a full training state.
pub fn load_state<P: AsRef<Path>>(path: P) -> Result<TrainState> {
    let mut f =
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC_STATE {
        bail!("not an sdegrad training-state checkpoint (bad magic)");
    }
    let iter = read_u64(&mut f)?;
    let adam_t = read_u64(&mut f)?;
    let fingerprint = read_u64(&mut f)?;
    let n = read_u64(&mut f)? as usize;
    let params = read_f64s(&mut f, n)?;
    let adam_m = read_f64s(&mut f, n)?;
    let adam_v = read_f64s(&mut f, n)?;
    Ok(TrainState { params, adam_m, adam_v, adam_t, iter, fingerprint })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test");
        let path = dir.join("p.bin");
        let params = vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE, 1e300];
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(params, loaded);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
        assert!(load_state(&path).is_err());
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test3");
        let path = dir.join("state.bin");
        let state = TrainState {
            params: vec![1.5, -2.25, 1e-300],
            adam_m: vec![0.125, -3.5, 0.0],
            adam_v: vec![4.0, 5e-5, 1e300],
            adam_t: 77,
            iter: 42,
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
        };
        save_state(&path, &state).unwrap();
        let loaded = load_state(&path).unwrap();
        assert_eq!(state, loaded);
    }

    #[test]
    fn formats_are_not_confusable() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test4");
        let p_params = dir.join("params.bin");
        let p_state = dir.join("state.bin");
        save_params(&p_params, &[1.0, 2.0]).unwrap();
        let state = TrainState {
            params: vec![1.0],
            adam_m: vec![0.0],
            adam_v: vec![0.0],
            adam_t: 1,
            iter: 1,
            fingerprint: 7,
        };
        save_state(&p_state, &state).unwrap();
        assert!(load_state(&p_params).is_err(), "params file read as state");
        assert!(load_params(&p_state).is_err(), "state file read as params");
    }

    /// Adam resumed from a saved state takes bit-identical steps —
    /// "training resumes exactly" at the optimizer level (the trainer-level
    /// pin lives in tests/trainer_batch.rs).
    #[test]
    fn optimizer_resume_via_state_is_exact() {
        use crate::optim::Adam;
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test5");
        let path = dir.join("resume.bin");
        let g = |i: u64| vec![(i as f64).sin(), (i as f64 * 0.5).cos(), -0.3];

        let mut full = Adam::new(3, 0.02);
        let mut p_full = vec![0.1, 0.2, 0.3];
        for i in 0..12 {
            full.step(&mut p_full, &g(i), 1.0);
        }

        let mut head = Adam::new(3, 0.02);
        let mut p_head = vec![0.1, 0.2, 0.3];
        for i in 0..6 {
            head.step(&mut p_head, &g(i), 1.0);
        }
        let (m, v, t) = head.state();
        save_state(
            &path,
            &TrainState {
                params: p_head.clone(),
                adam_m: m.to_vec(),
                adam_v: v.to_vec(),
                adam_t: t,
                iter: 6,
                fingerprint: 0,
            },
        )
        .unwrap();

        let st = load_state(&path).unwrap();
        let mut tail = Adam::from_state(0.02, st.adam_m, st.adam_v, st.adam_t);
        let mut p = st.params;
        for i in st.iter..12 {
            tail.step(&mut p, &g(i), 1.0);
        }
        assert_eq!(p, p_full, "resumed run diverged from uninterrupted run");
    }
}
