//! Flat-parameter checkpoints: little-endian f64 with a small header.

use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

const MAGIC: &[u8; 8] = b"SDEGRAD1";

/// Save a flat parameter vector.
pub fn save_params<P: AsRef<Path>>(path: P, params: &[f64]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    for v in params {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a flat parameter vector.
pub fn load_params<P: AsRef<Path>>(path: P) -> Result<Vec<f64>> {
    let mut f =
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an sdegrad checkpoint (bad magic)");
    }
    let mut len_bytes = [0u8; 8];
    f.read_exact(&mut len_bytes)?;
    let n = u64::from_le_bytes(len_bytes) as usize;
    let mut buf = vec![0u8; n * 8];
    f.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test");
        let path = dir.join("p.bin");
        let params = vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE, 1e300];
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(params, loaded);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("sdegrad_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
    }
}
