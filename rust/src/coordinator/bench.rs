//! `sdegrad bench throughput` — multi-path throughput of the batched SoA
//! execution engine vs the per-path (thread-per-path) engine.
//!
//! Measures **paths/sec** (forward solves) and **grad-paths/sec**
//! (stochastic-adjoint gradients) on two workloads:
//!
//! * the 10-d replicated GBM of §7.1 (cheap coefficients — measures
//!   engine overhead: dispatch, noise, stepping),
//! * the same GBM under **checkpointed backprop** (`gbm_d10_ckpt`:
//!   the O(√n)-memory schedule, gradients asserted identical to the
//!   full tape; peak-tape-bytes and recompute-NFE ride along as
//!   ungated "observed" rows), and
//! * the GBM fleet driven by the **virtual Brownian tree** with the
//!   ancestor node cache (`gbm_d10_cached`: results asserted identical
//!   to the cache-disabled tree; the observed `bridge_calls_per_step`
//!   row pins the amortized ≤2-draws/step contract on a dyadic grid),
//! * a neural-drift SDE (the latent posterior with MLP drift/diffusion —
//!   measures the batched matrix–matrix win on net-bound dynamics), and
//! * the minibatch ELBO engine on the persistent work-stealing pool
//!   (`neural_posterior_pooled`; the ungated `executor`/`overhead_us`
//!   row tracks raw dispatch cost).
//!
//! Both engines solve the *same problems from the same seeds* and are
//! bit-identical path-for-path (asserted here on every run), so the
//! numbers compare pure execution strategy. Results are printed as a
//! table and written to `BENCH_throughput.json` (hand-rolled JSON; the
//! crate set has no serde) for the CI artifact trajectory.
//!
//! `sdegrad bench compare` is the CI regression gate: it diffs a fresh
//! `BENCH_throughput.json` against the committed `BENCH_baseline.json`,
//! prints a markdown table (appended to the job summary when
//! `--summary`/`GITHUB_STEP_SUMMARY` is set), and exits nonzero when a
//! **batched** paths/sec or grad-paths/sec row regresses by more than the
//! threshold (default 25%). Refreshing the baseline is one command, run
//! on the reference machine — the committed baseline holds BOTH
//! harnesses' rows (per-record `"bench"` tags), and [`run_baseline`]
//! runs both and writes the merged file directly:
//!
//! ```text
//! cargo run --release -- bench baseline --quick
//! # rewrites BENCH_baseline.json (no placeholder flag) — commit it.
//! ```
//!
//! A baseline carrying `"placeholder": true` (the repo's initial state,
//! before anyone has measured on the reference machine) is reported but
//! never fails the job — and CI fails main outright if the flag is ever
//! reintroduced there (the `baseline-measured` guard in rust.yml).
//!
//! ## Kernel tiers in the bench
//!
//! Every batched workload is measured twice: on the default **exact**
//! tier (bit-identical to the per-path engine — asserted) and on the
//! opt-in **fast** tier (`{problem}_fast` rows: fused/blocked kernels,
//! validated against exact to [`FAST_RTOL`] relative before timing).
//! Fast rows keep engine `"batched"` so `bench compare` gates them
//! identically.
//!
//! `sdegrad bench serve` ([`run_serve_bench`]) is the serving load
//! harness: an in-process `sdegrad serve` instance under closed-loop
//! concurrent clients (req/sec + p50/p99 per endpoint) followed by an
//! **open-loop traffic simulator** — heavy-tail request sizes, bursty
//! exponential arrivals, and a deliberate overload episode against a
//! tiny admission budget — emitting `serve_p99_ms` and `shed_rate`
//! rows. All land in `BENCH_serve.json` (bench tag "serve");
//! `req_per_sec` rows are gated like the engine throughput rows, and
//! the open-loop p99/shed-rate rows are gated **lower-is-better** (an
//! increase past the threshold fails). The committed baseline merges
//! both harnesses' rows with per-record `"bench"` tags; each CI job
//! gates its own subset via `bench compare --subset throughput|serve`.

use crate::adjoint::AdjointConfig;
use crate::api::{
    sensitivity_batch, sensitivity_batch_per_path, solve_batch, solve_batch_local,
    solve_batch_per_path, Checkpointing, NoiseSpec, SdeProblem, SensAlg, SolveOptions,
    StepControl,
};
use crate::latent::{LatentSdeConfig, LatentSdeModel, PosteriorSde};
use crate::metrics::json::{json_num, json_number_field, json_str, json_string_field};
use crate::metrics::Stopwatch;
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;
use crate::sde::problems::{sample_experiment_setup, Example1};
use crate::sde::{BatchSdeVjp, KernelTier, ReplicatedSde};
use crate::solvers::Method;
use std::io::Write;

/// Relative agreement the fast tier must show against the exact tier
/// before its rows are timed. Fast kernels only reassociate and fuse
/// within-row arithmetic, so per-step drift is O(ulp); over the longest
/// bench horizon (1000 Milstein steps of multiplicative noise) the
/// accumulated divergence stays far inside this budget.
pub const FAST_RTOL: f64 = 1e-6;

/// Elementwise relative comparison for the fast-tier validity gates.
fn assert_close_rel(a: &[f64], b: &[f64], rtol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= rtol * scale,
            "{what}[{i}]: exact {x} vs fast {y} (rtol {rtol})"
        );
    }
}

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub problem: &'static str,
    pub metric: &'static str,
    pub engine: &'static str,
    pub paths: usize,
    pub steps: usize,
    pub value_per_sec: f64,
}

fn time_best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    // Best-of-N wall clock (throughput benches want the least-noisy run;
    // one warmup rep is included and discarded).
    let mut best = f64::INFINITY;
    f();
    for _ in 0..reps {
        let sw = Stopwatch::new();
        std::hint::black_box(f());
        best = best.min(sw.elapsed_s());
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn run_problem<S>(
    rows: &mut Vec<ThroughputRow>,
    name: &'static str,
    prob: &SdeProblem<'_, S>,
    method: Method,
    n_paths: usize,
    n_steps: usize,
    reps: usize,
    with_grad: bool,
) where
    S: BatchSdeVjp + Sync + ?Sized,
{
    let root = PrngKey::from_seed(0x7140);
    let replicates = prob.replicates(root, n_paths);
    let opts = SolveOptions::fixed(method, n_steps);

    // Correctness gate: the two engines must agree bit-for-bit before
    // their times are worth comparing.
    let batched = solve_batch(&replicates, &opts);
    let per_path = solve_batch_per_path(&replicates, &opts);
    for (a, b) in batched.iter().zip(&per_path) {
        assert_eq!(a.states, b.states, "engines diverged on {name}");
    }

    let t_batched = time_best_of(reps, || solve_batch(&replicates, &opts)[0].final_state()[0]);
    let t_scalar =
        time_best_of(reps, || solve_batch_per_path(&replicates, &opts)[0].final_state()[0]);
    for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
        rows.push(ThroughputRow {
            problem: name,
            metric: "paths_per_sec",
            engine,
            paths: n_paths,
            steps: n_steps,
            value_per_sec: n_paths as f64 / secs,
        });
    }

    if with_grad {
        let alg = SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: method,
            ..Default::default()
        });
        let step = StepControl::Steps(n_steps);
        let g_batched = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
        let g_per_path = sensitivity_batch_per_path(&replicates, &alg, step);
        for (a, b) in g_batched.iter().zip(&g_per_path) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "gradient engines diverged on {name}");
        }
        let t_batched = time_best_of(reps, || {
            sensitivity_batch(&replicates, &alg, step, ExecConfig::default())[0]
                .as_ref()
                .unwrap()
                .dtheta[0]
        });
        let t_scalar = time_best_of(reps, || {
            sensitivity_batch_per_path(&replicates, &alg, step)[0].as_ref().unwrap().dtheta[0]
        });
        for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
            rows.push(ThroughputRow {
                problem: name,
                metric: "grad_paths_per_sec",
                engine,
                paths: n_paths,
                steps: n_steps,
                value_per_sec: n_paths as f64 / secs,
            });
        }
    }
}

/// Run the throughput sweep; prints a table and writes
/// `BENCH_throughput.json`. `quick` shrinks paths/steps for CI smoke
/// runs.
pub fn run_throughput(quick: bool) -> Vec<ThroughputRow> {
    super::repro::headline("Throughput: batched SoA engine vs per-path engine");
    let (n_paths, n_steps, reps) = if quick { (256, 200, 3) } else { (2048, 1000, 5) };
    let mut rows = Vec::new();

    // 1. Replicated GBM, d = 10 (§7.1's system).
    let dim = 10;
    let gbm = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    run_problem(
        &mut rows,
        "gbm_d10",
        &prob,
        Method::MilsteinIto,
        n_paths,
        n_steps,
        reps,
        true,
    );

    // 1a. The same GBM fleet through the opt-in fast kernel tier
    // (`gbm_d10_fast`): fused drift+diffusion and blocked reductions.
    // Engine stays "batched" so `bench compare` gates these rows like
    // the exact ones. Validity gate before timing: every saved state and
    // every gradient must agree with the exact tier to FAST_RTOL.
    {
        let replicates = prob.replicates(PrngKey::from_seed(0x7140), n_paths);
        let opts = SolveOptions::fixed(Method::MilsteinIto, n_steps);
        let opts_fast = SolveOptions::fixed(Method::MilsteinIto, n_steps).tier(KernelTier::Fast);
        let exact = solve_batch(&replicates, &opts);
        let fast = solve_batch(&replicates, &opts_fast);
        for (a, b) in exact.iter().zip(&fast) {
            assert_close_rel(&a.states, &b.states, FAST_RTOL, "gbm_d10_fast solve");
        }
        let t_fast =
            time_best_of(reps, || solve_batch(&replicates, &opts_fast)[0].final_state()[0]);
        rows.push(ThroughputRow {
            problem: "gbm_d10_fast",
            metric: "paths_per_sec",
            engine: "batched",
            paths: n_paths,
            steps: n_steps,
            value_per_sec: n_paths as f64 / t_fast,
        });

        let alg = SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: Method::MilsteinIto,
            ..Default::default()
        });
        let step = StepControl::Steps(n_steps);
        let g_exact = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
        let g_fast =
            sensitivity_batch(&replicates, &alg, step, ExecConfig::new().tier(KernelTier::Fast));
        for (a, b) in g_exact.iter().zip(&g_fast) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_close_rel(&a.dtheta, &b.dtheta, FAST_RTOL, "gbm_d10_fast gradient");
        }
        let t_gfast = time_best_of(reps, || {
            sensitivity_batch(&replicates, &alg, step, ExecConfig::new().tier(KernelTier::Fast))
                [0]
                .as_ref()
                .unwrap()
                .dtheta[0]
        });
        rows.push(ThroughputRow {
            problem: "gbm_d10_fast",
            metric: "grad_paths_per_sec",
            engine: "batched",
            paths: n_paths,
            steps: n_steps,
            value_per_sec: n_paths as f64 / t_gfast,
        });
    }

    // 1b. Checkpointed backprop on the same GBM fleet: the O(√n)-memory
    // taped estimator (`Checkpointing::Sqrt`) whose gradients are
    // exact-f64-identical to the full tape (asserted below, so the gated
    // row measures pure recompute overhead, not a different answer). The
    // schedule's memory/recompute trade rides along as ungated
    // "observed" rows: peak live tape bytes and backward-pass recompute
    // NFE per path (raw values in the per-sec column, like the serve
    // latency rows).
    {
        let replicates = prob.replicates(PrngKey::from_seed(0x7142), n_paths);
        let step = StepControl::Steps(n_steps);
        let ckpt = SensAlg::Backprop {
            method: Method::MilsteinIto,
            checkpointing: Checkpointing::Sqrt,
        };
        let g_ckpt = sensitivity_batch(&replicates, &ckpt, step, ExecConfig::default());
        let g_tape = sensitivity_batch(
            &replicates,
            &SensAlg::backprop(Method::MilsteinIto),
            step,
            ExecConfig::default(),
        );
        for (a, b) in g_ckpt.iter().zip(&g_tape) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "checkpointed backprop diverged from the tape");
        }
        let g_per_path = sensitivity_batch_per_path(&replicates, &ckpt, step);
        for (a, b) in g_ckpt.iter().zip(&g_per_path) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "gradient engines diverged on gbm_d10_ckpt");
        }
        let t_batched = time_best_of(reps, || {
            sensitivity_batch(&replicates, &ckpt, step, ExecConfig::default())[0]
                .as_ref()
                .unwrap()
                .dtheta[0]
        });
        let t_scalar = time_best_of(reps, || {
            sensitivity_batch_per_path(&replicates, &ckpt, step)[0].as_ref().unwrap().dtheta[0]
        });
        for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
            rows.push(ThroughputRow {
                problem: "gbm_d10_ckpt",
                metric: "grad_paths_per_sec",
                engine,
                paths: n_paths,
                steps: n_steps,
                value_per_sec: n_paths as f64 / secs,
            });
        }
        let stats = &g_ckpt[0].as_ref().unwrap().stats;
        for (metric, value) in [
            ("peak_tape_bytes", stats.peak_tape_bytes as f64),
            ("recompute_nfe", stats.recompute_nfe as f64),
        ] {
            rows.push(ThroughputRow {
                problem: "gbm_d10_ckpt",
                metric,
                engine: "observed",
                paths: n_paths,
                steps: n_steps,
                value_per_sec: value,
            });
        }
    }

    // 1c. The same GBM fleet driven by the **virtual Brownian tree** with
    // the ancestor node cache (`gbm_d10_cached`): monotone solver sweeps
    // resume each bisection from the deepest cached ancestor, so bridge
    // draws amortize to O(1) per step instead of O(log n). A power-of-two
    // step count makes the grid dyadic, where the amortized bound is
    // exactly ≤ 2 draws/step (asserted on the observed row). Correctness
    // gate before timing: cached results equal the cache-disabled tree
    // bit-for-bit — the cache is purely a speed/memory knob.
    {
        let n_steps_dyadic = if quick { 256 } else { 1024 };
        let tree_prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0))
            .params(&theta)
            .noise(NoiseSpec::VirtualTree { tol: 1e-7 });
        let replicates = tree_prob.replicates(PrngKey::from_seed(0x7143), n_paths);
        let uncached: Vec<_> =
            replicates.iter().map(|p| p.clone().tree_cache(0)).collect();
        let opts = SolveOptions::fixed(Method::MilsteinIto, n_steps_dyadic);
        let cached_sols = solve_batch(&replicates, &opts);
        let uncached_sols = solve_batch(&uncached, &opts);
        for (a, b) in cached_sols.iter().zip(&uncached_sols) {
            assert_eq!(a.states, b.states, "node cache changed a gbm_d10_cached result");
        }
        let draws_per_step = cached_sols[0].noise.bridge_calls() as f64
            / cached_sols[0].stats.steps.max(1) as f64;
        assert!(
            draws_per_step <= 2.0,
            "node cache must amortize to ≤2 bridge draws/step on a dyadic sweep \
             (got {draws_per_step})"
        );
        rows.push(ThroughputRow {
            problem: "gbm_d10_cached",
            metric: "bridge_calls_per_step",
            engine: "observed",
            paths: n_paths,
            steps: n_steps_dyadic,
            value_per_sec: draws_per_step,
        });

        let t_cached =
            time_best_of(reps, || solve_batch(&replicates, &opts)[0].final_state()[0]);
        rows.push(ThroughputRow {
            problem: "gbm_d10_cached",
            metric: "paths_per_sec",
            engine: "batched",
            paths: n_paths,
            steps: n_steps_dyadic,
            value_per_sec: n_paths as f64 / t_cached,
        });

        let alg = SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: Method::MilsteinIto,
            ..Default::default()
        });
        let step = StepControl::Steps(n_steps_dyadic);
        let g_cached = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
        let g_uncached = sensitivity_batch(&uncached, &alg, step, ExecConfig::default());
        for (a, b) in g_cached.iter().zip(&g_uncached) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "node cache changed a gbm_d10_cached gradient");
        }
        let t_gcached = time_best_of(reps, || {
            sensitivity_batch(&replicates, &alg, step, ExecConfig::default())[0]
                .as_ref()
                .unwrap()
                .dtheta[0]
        });
        rows.push(ThroughputRow {
            problem: "gbm_d10_cached",
            metric: "grad_paths_per_sec",
            engine: "batched",
            paths: n_paths,
            steps: n_steps_dyadic,
            value_per_sec: n_paths as f64 / t_gcached,
        });
    }

    // 2. Neural-drift SDE: the latent posterior (MLP drift + per-dim
    // diffusion nets) — the workload where batched net evaluation pays.
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 3,
        latent_dim: 4,
        context_dim: 1,
        hidden: 64,
        diff_hidden: 16,
        enc_hidden: 16,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(4));
    let post = PosteriorSde::new(&model);
    let mut theta_full = params[..post.sde_param_len()].to_vec();
    theta_full.push(0.3); // static context slot
    let aug = crate::sde::Sde::state_dim(&post);
    let y0 = vec![0.1; aug];
    // PosteriorSde carries interior-mutable scratch (not Sync), so both
    // engines run single-threaded here: batched kernel vs sequential
    // scalar solves — a pure engine comparison at equal thread counts.
    let (nn_paths, nn_steps) = if quick { (64, 50) } else { (256, 200) };
    let nn_prob = SdeProblem::new(&post, &y0, (0.0, 0.5)).params(&theta_full);
    let nn_replicates = nn_prob.replicates(PrngKey::from_seed(0x7141), nn_paths);
    let nn_opts = SolveOptions::fixed(Method::Heun, nn_steps);
    let batched = solve_batch_local(&nn_replicates, &nn_opts);
    let sequential: Vec<_> = nn_replicates.iter().map(|p| p.solve(&nn_opts)).collect();
    for (a, b) in batched.iter().zip(&sequential) {
        assert_eq!(a.states, b.states, "engines diverged on neural_posterior");
    }
    let t_batched =
        time_best_of(reps, || solve_batch_local(&nn_replicates, &nn_opts)[0].final_state()[0]);
    let t_scalar = time_best_of(reps, || {
        nn_replicates.iter().map(|p| p.solve(&nn_opts).final_state()[0]).sum()
    });
    for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
        rows.push(ThroughputRow {
            problem: "neural_posterior",
            metric: "paths_per_sec",
            engine,
            paths: nn_paths,
            steps: nn_steps,
            value_per_sec: nn_paths as f64 / secs,
        });
    }

    // 2a. The neural workload on the fast tier (`neural_posterior_fast`):
    // the blocked matrix–matrix MLP kernels are where the tier earns its
    // keep. Same validity gate: tolerance against the exact solution.
    {
        let nn_opts_fast = SolveOptions::fixed(Method::Heun, nn_steps).tier(KernelTier::Fast);
        let fast = solve_batch_local(&nn_replicates, &nn_opts_fast);
        for (a, b) in batched.iter().zip(&fast) {
            assert_close_rel(&a.states, &b.states, FAST_RTOL, "neural_posterior_fast solve");
        }
        let t_fast = time_best_of(reps, || {
            solve_batch_local(&nn_replicates, &nn_opts_fast)[0].final_state()[0]
        });
        rows.push(ThroughputRow {
            problem: "neural_posterior_fast",
            metric: "paths_per_sec",
            engine: "batched",
            paths: nn_paths,
            steps: nn_steps,
            value_per_sec: nn_paths as f64 / t_fast,
        });
    }

    // 2b. The minibatch ELBO engine on the persistent pool
    // (`neural_posterior_pooled`): chunks of the M·S posterior paths fan
    // out through `runtime::scoped_map` — the end-to-end trainer
    // iteration the pool exists for. Correctness gate before timing: the
    // pooled result equals the single-worker run exactly (path-ordered
    // reduction; any schedule computes the same floats).
    {
        use crate::latent::{elbo_step_batch, ElboConfig};
        let (m_seqs, s_samples, n_obs) = if quick { (8, 2, 6) } else { (16, 4, 10) };
        let dx = 3; // matches the model above
        let e_times: Vec<f64> = (0..n_obs).map(|k| 0.08 * k as f64).collect();
        let mut obs_data = vec![0.0; m_seqs * n_obs * dx];
        PrngKey::from_seed(0x7144).fill_normal(0, &mut obs_data);
        let obs_seqs: Vec<&[f64]> = obs_data.chunks(n_obs * dx).collect();
        let keys: Vec<PrngKey> =
            (0..m_seqs).map(|m| PrngKey::from_seed(0x7145).fold_in(m as u64)).collect();
        let ecfg = ElboConfig::default();
        let workers = crate::runtime::worker_count();
        let pooled = elbo_step_batch(
            &model, &params, &e_times, &obs_seqs, &keys, &ecfg, s_samples, workers,
        );
        let solo =
            elbo_step_batch(&model, &params, &e_times, &obs_seqs, &keys, &ecfg, s_samples, 1);
        assert_eq!(pooled.loss, solo.loss, "pooled ELBO loss diverged from single-worker");
        assert_eq!(pooled.grad, solo.grad, "pooled ELBO gradient diverged from single-worker");
        let elbo_paths = m_seqs * s_samples;
        let t_pooled = time_best_of(reps, || {
            elbo_step_batch(&model, &params, &e_times, &obs_seqs, &keys, &ecfg, s_samples, workers)
                .loss
        });
        rows.push(ThroughputRow {
            problem: "neural_posterior_pooled",
            metric: "paths_per_sec",
            engine: "batched",
            paths: elbo_paths,
            steps: (n_obs - 1) * ecfg.substeps,
            value_per_sec: elbo_paths as f64 / t_pooled,
        });
    }

    // 3. Executor dispatch overhead: microseconds per `scoped_map`
    // fan-out of trivial tasks on the persistent pool — what a batched
    // call pays over a sequential loop now that workers are parked
    // instead of respawned (observed, not gated).
    {
        let n_tasks = crate::runtime::worker_count().max(2) * 4;
        let exec_reps = 200;
        let sw = Stopwatch::new();
        for _ in 0..exec_reps {
            std::hint::black_box(crate::runtime::scoped_map(n_tasks, usize::MAX, |i| i));
        }
        let overhead_us = sw.elapsed_s() * 1e6 / exec_reps as f64;
        rows.push(ThroughputRow {
            problem: "executor",
            metric: "overhead_us",
            engine: "observed",
            paths: n_tasks,
            steps: exec_reps,
            value_per_sec: overhead_us,
        });
    }

    // 4. Tracing overhead: the gbm_d10 batched solve timed with span
    // collection off vs on (observed, not gated — the acceptance target
    // is < 2% on this problem). A negative reading is timer noise and is
    // clamped to 0. The prior enabled state is restored afterwards, and
    // the span sink is drained unless a `--trace-out` run owns it.
    {
        let replicates = prob.replicates(PrngKey::from_seed(0x7141), n_paths);
        let opts = SolveOptions::fixed(Method::MilsteinIto, n_steps);
        let was_enabled = crate::obs::enabled();
        crate::obs::set_enabled(false);
        let t_off = time_best_of(reps, || solve_batch(&replicates, &opts)[0].final_state()[0]);
        crate::obs::set_enabled(true);
        let t_on = time_best_of(reps, || solve_batch(&replicates, &opts)[0].final_state()[0]);
        crate::obs::set_enabled(was_enabled);
        if !was_enabled {
            crate::obs::clear_events();
        }
        rows.push(ThroughputRow {
            problem: "tracing",
            metric: "trace_overhead_pct",
            engine: "observed",
            paths: n_paths,
            steps: n_steps,
            value_per_sec: ((t_on / t_off - 1.0) * 100.0).max(0.0),
        });
    }

    println!(
        "{:<18} {:>20} {:>10} {:>7} {:>7} {:>14}",
        "problem", "metric", "engine", "paths", "steps", "per_sec"
    );
    for r in &rows {
        println!(
            "{:<18} {:>20} {:>10} {:>7} {:>7} {:>14.0}",
            r.problem, r.metric, r.engine, r.paths, r.steps, r.value_per_sec
        );
    }
    for metric in ["paths_per_sec", "grad_paths_per_sec"] {
        for problem in ["gbm_d10", "gbm_d10_ckpt", "neural_posterior"] {
            let get = |engine: &str| {
                rows.iter()
                    .find(|r| r.metric == metric && r.problem == problem && r.engine == engine)
                    .map(|r| r.value_per_sec)
            };
            if let (Some(b), Some(s)) = (get("batched"), get("per_path")) {
                println!("speedup {problem}/{metric}: {:.2}x", b / s);
            }
        }
    }
    // Fast-tier acceptance signal: fast vs exact, batched engine on both
    // sides (the ≥1.5× target for grad paths lives in the CI summary, not
    // a hard assert — hardware varies).
    for (fast_p, exact_p, metric) in [
        ("gbm_d10_fast", "gbm_d10", "paths_per_sec"),
        ("gbm_d10_fast", "gbm_d10", "grad_paths_per_sec"),
        ("neural_posterior_fast", "neural_posterior", "paths_per_sec"),
    ] {
        let get = |problem: &str| {
            rows.iter()
                .find(|r| r.metric == metric && r.problem == problem && r.engine == "batched")
                .map(|r| r.value_per_sec)
        };
        if let (Some(f), Some(e)) = (get(fast_p), get(exact_p)) {
            println!("fast-tier speedup {exact_p}/{metric}: {:.2}x", f / e);
        }
    }

    write_json("BENCH_throughput.json", "throughput", quick, &rows)
        .expect("writing BENCH_throughput.json");
    println!("(JSON: BENCH_throughput.json)");
    rows
}

fn write_json(
    path: &str,
    bench: &str,
    quick: bool,
    rows: &[ThroughputRow],
) -> std::io::Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "{{")?;
    writeln!(out, "  \"bench\": {},", json_str(bench))?;
    writeln!(out, "  \"quick\": {quick},")?;
    writeln!(out, "  \"root_seed\": {},", 0x7140)?;
    writeln!(out, "  \"results\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            out,
            "    {{\"problem\": {}, \"metric\": {}, \"engine\": {}, \"paths\": {}, \
             \"steps\": {}, \"value_per_sec\": {}}}{comma}",
            json_str(r.problem),
            json_str(r.metric),
            json_str(r.engine),
            r.paths,
            r.steps,
            json_num(r.value_per_sec),
        )?;
    }
    writeln!(out, "  ]")?;
    writeln!(out, "}}")?;
    out.flush()
}

// ---------------------------------------------------------------------
// `sdegrad bench serve` — the in-process serving load harness.
// ---------------------------------------------------------------------

/// In-process load harness for `sdegrad serve`, in two phases:
///
/// **Closed loop** — starts a server on an ephemeral port over a
/// synthetic (untrained — serving does not care) latent-SDE model,
/// fires N concurrent client threads of simulate and ELBO-scoring
/// requests, and reports **req/sec** plus p50/p99 latency per endpoint.
/// Before timing, one response per endpoint is asserted byte-identical
/// to the per-request scalar engine call (the serving determinism
/// contract), so the numbers measure a *correct* server.
///
/// **Open loop** ([`open_loop_serve_phase`]) — a traffic simulator with
/// deterministic exponential inter-arrivals, heavy-tail request sizes,
/// and a deliberate burst overload episode against a small admission
/// budget. Every 200 is asserted byte-identical to the scalar oracle,
/// every 429 well-formed (`Retry-After` + `overloaded` body), zero
/// connection resets tolerated. Emits gated `serve_p99_ms` and
/// `shed_rate` rows (lower is better — `bench compare` gates them
/// direction-aware) plus observed p50/offered-rate rows.
///
/// Results land in `BENCH_serve.json` in the shared BENCH format:
/// `req_per_sec` / `serve_p99_ms` / `shed_rate` rows are gated by
/// `sdegrad bench compare` (engine "batched"), the rest ride along
/// ungated (engine "observed").
///
/// `exec` carries the kernel tier (`sdegrad bench serve --tier fast`);
/// the scalar oracle scores under the same tier, so the byte-identity
/// gate holds on both tiers.
pub fn run_serve_bench(quick: bool, exec: ExecConfig) -> Vec<ThroughputRow> {
    use crate::latent::{LatentSdeConfig, LatentSdeModel};
    use crate::serve::batcher::scalar_response;
    use crate::serve::client::post as http_post;
    use crate::serve::{protocol, ModelRegistry, ServeConfig, Server};
    use std::time::Instant;

    super::repro::headline("Serving: dynamic micro-batching load harness");
    println!("kernel tier: {}", exec.tier.name());
    let (n_clients, reqs_per_client) = if quick { (4, 20) } else { (8, 100) };

    let cfg = LatentSdeConfig {
        obs_dim: 1,
        latent_dim: 4,
        context_dim: 1,
        hidden: 32,
        diff_hidden: 8,
        enc_hidden: 32,
        obs_noise_std: 0.05,
        ..Default::default()
    };
    let model = LatentSdeModel::new(cfg);
    let params = model.init_params(PrngKey::from_seed(0x5e21));
    let mut registry = ModelRegistry::new();
    registry.insert("default", model, params).expect("registering bench model");

    let times: Vec<f64> = (0..12).map(|k| 0.1 * k as f64).collect();
    let times_json =
        format!("[{}]", times.iter().map(|t| format!("{t}")).collect::<Vec<_>>().join(","));
    let mut obs = vec![0.0; times.len()];
    PrngKey::from_seed(0x5e22).fill_normal(0, &mut obs);
    let obs_json = format!(
        "[{}]",
        obs.iter().map(|x| format!("[{x}]")).collect::<Vec<_>>().join(",")
    );
    let simulate_body = |seed: u64| {
        format!("{{\"seed\": {seed}, \"times\": {times_json}, \"substeps\": 3}}")
    };
    let elbo_body = |seed: u64| {
        format!(
            "{{\"seed\": {seed}, \"times\": {times_json}, \"obs\": {obs_json}, \
             \"substeps\": 3, \"samples\": 2, \"kl_weight\": 0.5}}"
        )
    };

    // Cache off: the harness measures the engine + batcher, not HashMap
    // lookups. Each request carries a distinct seed anyway.
    let server = Server::start(
        registry,
        ServeConfig {
            port: 0,
            workers: n_clients,
            max_batch: 16,
            max_wait_us: 200,
            cache_capacity: 0,
            exec,
            ..Default::default()
        },
    )
    .expect("starting bench server");
    let addr = server.addr();

    // Correctness gate before timing: served bytes == scalar oracle.
    {
        // A throwaway registry clone for the oracle (Server consumed ours).
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(0x5e21));
        let mut oracle_reg = ModelRegistry::new();
        oracle_reg.insert("default", model, params).unwrap();
        let entry = oracle_reg.get("default").unwrap();
        for (path, body) in
            [("/v1/simulate", simulate_body(99)), ("/v1/elbo", elbo_body(99))]
        {
            let (status, served) = http_post(addr, path, &body).expect("bench request failed");
            assert_eq!(status, 200, "bench {path} request failed: {served:?}");
            let req = protocol::parse_request(path, &body).unwrap();
            let expected = scalar_response(entry, &req, exec.tier).unwrap();
            assert_eq!(served, expected, "served {path} diverged from the scalar oracle");
        }
    }

    let mut rows = Vec::new();
    type BodyFn<'f> = &'f (dyn Fn(u64) -> String + Sync);
    for (endpoint, path, make_body) in [
        ("serve_simulate", "/v1/simulate", &simulate_body as BodyFn<'_>),
        ("serve_elbo", "/v1/elbo", &elbo_body as BodyFn<'_>),
    ] {
        let total = n_clients * reqs_per_client;
        let sw = Stopwatch::new();
        let latencies: Vec<f64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut lats = Vec::with_capacity(reqs_per_client);
                        for i in 0..reqs_per_client {
                            let seed = (c * reqs_per_client + i) as u64;
                            let body = make_body(seed);
                            let t0 = Instant::now();
                            let (status, resp) =
                                http_post(addr, path, &body).expect("bench request failed");
                            lats.push(t0.elapsed().as_secs_f64() * 1e6);
                            // A non-200 mid-run means the server broke; its
                            // timing must not count as served traffic.
                            assert_eq!(status, 200, "bench {path} got an error: {resp:?}");
                            assert!(!resp.is_empty(), "empty response body");
                        }
                        lats
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("client panicked")).collect()
        });
        let elapsed = sw.elapsed_s();
        let mut sorted = latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let p50 = crate::metrics::percentile_of_sorted(&sorted, 0.50);
        let p99 = crate::metrics::percentile_of_sorted(&sorted, 0.99);
        println!(
            "{endpoint}: {total} requests, {n_clients} clients: {:.0} req/s, \
             p50 {:.0} µs, p99 {:.0} µs",
            total as f64 / elapsed,
            p50,
            p99
        );
        rows.push(ThroughputRow {
            problem: endpoint,
            metric: "req_per_sec",
            engine: "batched",
            paths: total,
            steps: times.len(),
            value_per_sec: total as f64 / elapsed,
        });
        for (metric, value) in [("p50_us", p50), ("p99_us", p99)] {
            rows.push(ThroughputRow {
                problem: endpoint,
                metric,
                engine: "observed",
                paths: total,
                steps: times.len(),
                value_per_sec: value,
            });
        }
    }
    server.shutdown();

    rows.extend(open_loop_serve_phase(quick, exec));

    write_json("BENCH_serve.json", "serve", quick, &rows).expect("writing BENCH_serve.json");
    println!("(JSON: BENCH_serve.json)");
    rows
}

/// [`run_serve_bench`] with an explicit kernel tier — superseded by the
/// [`ExecConfig`] parameter on the base name.
#[deprecated(
    since = "0.2.0",
    note = "use `run_serve_bench(quick, ExecConfig::new().tier(tier))`"
)]
pub fn run_serve_bench_tier(quick: bool, tier: KernelTier) -> Vec<ThroughputRow> {
    run_serve_bench(quick, ExecConfig::new().tier(tier))
}

/// One scheduled open-loop request: fire time (µs from phase start),
/// endpoint, JSON body.
struct OpenLoopArrival {
    at_us: u64,
    path: &'static str,
    body: String,
}

/// Build a deterministic heavy-tail traffic trace: request `i`'s shape
/// comes from `PrngKey::fold_in(i)`, so the trace is identical on every
/// run/machine. Lengths are Pareto(α≈1.1) with min 8 / cap 96 obs
/// points; ~25% of requests are ELBO scores (2 samples), the rest
/// simulates; arrivals are exponential with `mean_gap_us` (0 = a
/// simultaneous burst).
fn open_loop_trace(
    key: PrngKey,
    n: usize,
    first_seed: u64,
    mean_gap_us: f64,
) -> Vec<OpenLoopArrival> {
    let mut clock_us = 0.0f64;
    (0..n)
        .map(|i| {
            let k = key.fold_in(i as u64);
            if mean_gap_us > 0.0 {
                clock_us += -mean_gap_us * (1.0 - k.uniform(0)).ln();
            }
            let n_times =
                ((8.0 * (1.0 - k.uniform(1)).powf(-1.0 / 1.1)) as usize).clamp(8, 96);
            let times_json = format!(
                "[{}]",
                (0..n_times)
                    .map(|j| format!("{}", 0.05 * j as f64))
                    .collect::<Vec<_>>()
                    .join(",")
            );
            let seed = first_seed + i as u64;
            if k.uniform(2) < 0.25 {
                let mut obs = vec![0.0; n_times];
                k.fill_normal(3, &mut obs);
                let obs_json = format!(
                    "[{}]",
                    obs.iter().map(|x| format!("[{x}]")).collect::<Vec<_>>().join(",")
                );
                OpenLoopArrival {
                    at_us: clock_us as u64,
                    path: "/v1/elbo",
                    body: format!(
                        "{{\"seed\": {seed}, \"times\": {times_json}, \"obs\": {obs_json}, \
                         \"substeps\": 2, \"samples\": 2, \"kl_weight\": 0.5}}"
                    ),
                }
            } else {
                OpenLoopArrival {
                    at_us: clock_us as u64,
                    path: "/v1/simulate",
                    body: format!(
                        "{{\"seed\": {seed}, \"times\": {times_json}, \"substeps\": 2}}"
                    ),
                }
            }
        })
        .collect()
}

/// Fire a trace open-loop (requests launch at their scheduled times,
/// regardless of completions) and return per-request
/// `(index, status, headers, decoded body, latency_ms)`. Any transport
/// error — a connection reset most importantly — panics the bench: the
/// overload contract is "oracle bytes or a well-formed 429", never a
/// broken socket.
fn fire_open_loop(
    addr: std::net::SocketAddr,
    arrivals: &[OpenLoopArrival],
) -> Vec<(usize, u16, String, Vec<u8>, f64)> {
    use crate::serve::client::request_with_headers;
    use std::time::{Duration, Instant};
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let handles: Vec<_> = arrivals
            .iter()
            .enumerate()
            .map(|(i, a)| {
                let target = t0 + Duration::from_micros(a.at_us);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                scope.spawn(move || {
                    let t = Instant::now();
                    let (status, head, body) =
                        request_with_headers(addr, "POST", a.path, &a.body)
                            .expect("open-loop connection failed (reset?)");
                    (i, status, head, body, t.elapsed().as_secs_f64() * 1e3)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("open-loop client panicked")).collect()
    })
}

/// The open-loop phase of [`run_serve_bench`]: steady exponential
/// traffic, then a deliberate burst overload episode against a tiny
/// admission budget. Asserts the full overload contract on every
/// response and emits the gated `serve_p99_ms` / `shed_rate` rows.
fn open_loop_serve_phase(quick: bool, exec: ExecConfig) -> Vec<ThroughputRow> {
    use crate::latent::{LatentSdeConfig, LatentSdeModel};
    use crate::serve::batcher::scalar_response;
    use crate::serve::{protocol, ModelRegistry, ServeConfig, Server};
    use std::time::Instant;

    super::repro::headline("Serving: open-loop traffic simulator");
    let (n_steady, n_burst, mean_gap_us) =
        if quick { (60, 30, 1500.0) } else { (300, 120, 800.0) };

    let cfg = LatentSdeConfig {
        obs_dim: 1,
        latent_dim: 4,
        context_dim: 1,
        hidden: 32,
        diff_hidden: 8,
        enc_hidden: 32,
        obs_noise_std: 0.05,
        ..Default::default()
    };
    let build_registry = || {
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(0x5e21));
        let mut reg = ModelRegistry::new();
        reg.insert("default", model, params).expect("registering bench model");
        reg
    };

    // A 12-cell budget: the smallest request is 8 cells, so ANY submit
    // that finds the shard queue non-empty sheds — the burst episode is
    // guaranteed to shed as soon as two requests overlap. The
    // 2 KiB stream threshold makes long simulate responses exercise the
    // chunked path under load.
    let server = Server::start(
        build_registry(),
        ServeConfig {
            port: 0,
            workers: 8,
            max_batch: 16,
            max_wait_us: 200,
            shards: 2,
            queue_cells: 12,
            stream_threshold_bytes: 2048,
            cache_capacity: 0,
            exec,
            ..Default::default()
        },
    )
    .expect("starting open-loop bench server");
    let addr = server.addr();

    let key = PrngKey::from_seed(0x10ad);
    let steady = open_loop_trace(key, n_steady, 0, mean_gap_us);
    let t_phase = Instant::now();
    let mut outcomes = fire_open_loop(addr, &steady);
    let mut traces = vec![steady];

    // The overload episode: a simultaneous burst. One burst sheds with
    // near-certainty against the 12-cell budget; retry (fresh seeds —
    // the trace stays deterministic) in the measure-zero case every
    // burst request found an empty queue.
    let mut burst_no = 0u64;
    loop {
        let first_seed = 1_000_000 * (burst_no + 1);
        let burst = open_loop_trace(key.fold_in(100 + burst_no), n_burst, first_seed, 0.0);
        let burst_out = fire_open_loop(addr, &burst);
        let shed_here = burst_out.iter().filter(|o| o.1 == 429).count();
        let offset = traces.iter().map(|t| t.len()).sum::<usize>();
        outcomes.extend(burst_out.into_iter().map(|(i, s, h, b, l)| (offset + i, s, h, b, l)));
        traces.push(burst);
        burst_no += 1;
        if shed_here > 0 || burst_no >= 3 {
            break;
        }
    }
    let elapsed_s = t_phase.elapsed().as_secs_f64();
    server.shutdown();
    let arrivals: Vec<OpenLoopArrival> = traces.into_iter().flatten().collect();

    // The overload contract, request by request: oracle bytes on 200, a
    // well-formed 429 (Retry-After + "overloaded" body) on shed, nothing
    // else.
    let oracle_reg = build_registry();
    let entry = oracle_reg.get("default").expect("oracle model");
    let mut ok_lat_ms: Vec<f64> = Vec::new();
    let mut shed = 0usize;
    let mut streamed = 0usize;
    for (i, status, head, body, lat_ms) in outcomes {
        match status {
            200 => {
                let req = protocol::parse_request(arrivals[i].path, &arrivals[i].body)
                    .expect("trace request parses");
                let expected = scalar_response(entry, &req, exec.tier).unwrap();
                assert_eq!(
                    body, expected,
                    "open-loop 200 for request {i} diverged from the scalar oracle"
                );
                if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
                    streamed += 1;
                }
                ok_lat_ms.push(lat_ms);
            }
            429 => {
                assert!(
                    head.contains("Retry-After:"),
                    "429 without Retry-After for request {i}:\n{head}"
                );
                let text = std::str::from_utf8(&body).expect("429 body is UTF-8");
                assert!(
                    text.contains("\"overloaded\""),
                    "429 body missing the overloaded code: {text}"
                );
                shed += 1;
            }
            other => panic!(
                "open-loop request {i} got status {other}: {:?}",
                String::from_utf8_lossy(&body)
            ),
        }
    }
    let total = arrivals.len();
    assert!(!ok_lat_ms.is_empty(), "open-loop phase served nothing");
    assert!(shed > 0, "the overload episode never shed — admission control inert");
    assert!(streamed > 0, "no long simulate response streamed chunked");
    ok_lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let p50 = crate::metrics::percentile_of_sorted(&ok_lat_ms, 0.50);
    let p99 = crate::metrics::percentile_of_sorted(&ok_lat_ms, 0.99);
    let shed_rate = shed as f64 / total as f64;
    println!(
        "open loop: {total} offered ({:.0}/s), {} served, {shed} shed ({:.1}%), \
         {streamed} streamed, p50 {p50:.2} ms, p99 {p99:.2} ms",
        total as f64 / elapsed_s,
        ok_lat_ms.len(),
        shed_rate * 100.0
    );
    let row = |metric: &'static str, engine: &'static str, value: f64| ThroughputRow {
        problem: "serve_open_loop",
        metric,
        engine,
        paths: total,
        steps: 96,
        value_per_sec: value,
    };
    vec![
        // Gated, lower-is-better (bench compare special-cases both).
        row("serve_p99_ms", "batched", p99),
        row("shed_rate", "batched", shed_rate),
        // Context rows.
        row("p50_ms", "observed", p50),
        row("offered_req_per_sec", "observed", total as f64 / elapsed_s),
    ]
}

// ---------------------------------------------------------------------
// `sdegrad bench baseline` — measure + rewrite the regression baseline.
// ---------------------------------------------------------------------

/// `sdegrad bench baseline`: run BOTH harnesses on this machine and
/// rewrite the merged committed baseline in one step (per-row `"bench"`
/// tags, **no** placeholder flag). This replaces the old hand-merge
/// instructions — refreshing the baseline is now a single command on
/// the reference machine, so the placeholder state cannot persist for
/// lack of tooling.
pub fn run_baseline(quick: bool, out: &str) {
    super::repro::headline("Measuring the bench regression baseline");
    let throughput = run_throughput(quick);
    let serve = run_serve_bench(quick, ExecConfig::default());
    let parts: [(&str, &[ThroughputRow]); 2] =
        [("throughput", &throughput), ("serve", &serve)];
    write_baseline_json(out, quick, &parts).expect("writing baseline");
    println!(
        "wrote {} measured rows to {out} (no placeholder flag) — commit it to update \
         the gate.",
        throughput.len() + serve.len()
    );
}

/// Write the merged baseline file: [`write_json`]'s shape plus a
/// per-record `"bench"` tag, which is how `bench compare --subset` tells
/// the harnesses' rows apart in one file.
pub fn write_baseline_json(
    path: &str,
    quick: bool,
    parts: &[(&str, &[ThroughputRow])],
) -> std::io::Result<()> {
    let total: usize = parts.iter().map(|(_, rows)| rows.len()).sum();
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "{{")?;
    writeln!(out, "  \"bench\": \"baseline\",")?;
    writeln!(out, "  \"quick\": {quick},")?;
    writeln!(out, "  \"root_seed\": {},", 0x7140)?;
    writeln!(out, "  \"results\": [")?;
    let mut i = 0usize;
    for (tag, rows) in parts {
        for r in *rows {
            i += 1;
            let comma = if i == total { "" } else { "," };
            writeln!(
                out,
                "    {{\"bench\": {}, \"problem\": {}, \"metric\": {}, \"engine\": {}, \
                 \"paths\": {}, \"steps\": {}, \"value_per_sec\": {}}}{comma}",
                json_str(tag),
                json_str(r.problem),
                json_str(r.metric),
                json_str(r.engine),
                r.paths,
                r.steps,
                json_num(r.value_per_sec),
            )?;
        }
    }
    writeln!(out, "  ]")?;
    writeln!(out, "}}")?;
    out.flush()
}

// ---------------------------------------------------------------------
// `sdegrad bench compare` — the CI bench-regression gate.
// ---------------------------------------------------------------------

/// One parsed benchmark record from a `BENCH_*.json` file.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Which harness produced the row ("throughput", "serve", …): a
    /// per-record `"bench"` tag when present (the merged committed
    /// baseline carries one per row), else the file-level `"bench"`
    /// field. Lets `compare --subset` gate one harness's rows against a
    /// baseline that holds several.
    pub bench: String,
    pub problem: String,
    pub metric: String,
    pub engine: String,
    pub value_per_sec: f64,
}

/// A parsed `BENCH_*.json`: records plus the placeholder flag (a
/// committed baseline that has not been measured yet).
#[derive(Clone, Debug)]
pub struct BenchFile {
    pub placeholder: bool,
    pub records: Vec<BenchRecord>,
}

/// Parse the hand-rolled bench JSON (the exact shape [`write_json`]
/// emits — a scan over our own format via the shared
/// [`crate::metrics::json`] field scanners, not a general JSON parse).
pub fn parse_bench_json(text: &str) -> Result<BenchFile, String> {
    let placeholder = text.contains("\"placeholder\": true");
    let at = text.find("\"results\"").ok_or("missing \"results\" array")?;
    // The file-level bench tag must come from the header (scanning the
    // whole text could hit a per-record tag instead).
    let file_bench = json_string_field(&text[..at], "bench").unwrap_or_default();
    let arr = &text[at..];
    let open = arr.find('[').ok_or("missing [ after \"results\"")?;
    let close = arr.rfind(']').ok_or("missing ] closing \"results\"")?;
    let mut rest = &arr[open + 1..close];
    let mut records = Vec::new();
    while let Some(s) = rest.find('{') {
        let e = rest[s..].find('}').ok_or("unterminated result object")? + s;
        let block = &rest[s..=e];
        let get = |key: &str| {
            json_string_field(block, key).ok_or_else(|| format!("missing {key} in {block}"))
        };
        records.push(BenchRecord {
            bench: json_string_field(block, "bench").unwrap_or_else(|| file_bench.clone()),
            problem: get("problem")?,
            metric: get("metric")?,
            engine: get("engine")?,
            value_per_sec: json_number_field(block, "value_per_sec")
                .ok_or_else(|| format!("missing value_per_sec in {block}"))?,
        });
        rest = &rest[e + 1..];
    }
    Ok(BenchFile { placeholder, records })
}

/// Keep only one harness's records (`--subset throughput|serve`), so a
/// job can gate its own rows against the merged committed baseline
/// without the other harness's rows reading as "missing".
pub fn filter_bench(file: &BenchFile, subset: &str) -> BenchFile {
    BenchFile {
        placeholder: file.placeholder,
        records: file.records.iter().filter(|r| r.bench == subset).cloned().collect(),
    }
}

/// One baseline-vs-current comparison row.
#[derive(Clone, Debug)]
pub struct CompareRow {
    pub problem: String,
    pub metric: String,
    pub engine: String,
    pub baseline: f64,
    pub current: f64,
    /// Relative change `current/baseline − 1` (negative = regression).
    pub delta: f64,
    /// Whether this row can fail the gate (batched paths/grad-paths only;
    /// the per-path engine rows are informational context).
    pub gated: bool,
    pub failed: bool,
}

/// The gate's verdict over all baseline rows.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub rows: Vec<CompareRow>,
    pub failures: Vec<String>,
    pub placeholder: bool,
}

impl CompareReport {
    /// Exit status the CI job should use: failures only count against a
    /// real (non-placeholder) baseline.
    pub fn passed(&self) -> bool {
        self.placeholder || self.failures.is_empty()
    }
}

/// Diff `current` against `baseline`: a gated row fails when its
/// throughput drops by more than `threshold` (e.g. 0.25 = 25%) or is
/// missing from the current run.
pub fn compare_throughput(
    baseline: &BenchFile,
    current: &BenchFile,
    threshold: f64,
) -> CompareReport {
    let mut rows = Vec::new();
    let mut failures = Vec::new();
    for b in &baseline.records {
        let gated = b.engine == "batched"
            && (b.metric == "paths_per_sec"
                || b.metric == "grad_paths_per_sec"
                || b.metric == "req_per_sec"
                || b.metric == "serve_p99_ms"
                || b.metric == "shed_rate");
        // Latency and shed-rate rows gate in the opposite direction: an
        // INCREASE is the regression.
        let lower_is_better = matches!(b.metric.as_str(), "serve_p99_ms" | "shed_rate");
        let found = current
            .records
            .iter()
            .find(|c| c.problem == b.problem && c.metric == b.metric && c.engine == b.engine);
        let (current_v, delta, failed) = match found {
            Some(c) => {
                // Lower-is-better baselines can legitimately sit at ~0
                // (e.g. a zero shed rate), where a ratio blows up — gate
                // those on absolute excess instead of a percentage.
                let delta = if b.value_per_sec > 0.0 {
                    c.value_per_sec / b.value_per_sec - 1.0
                } else {
                    c.value_per_sec - b.value_per_sec
                };
                let failed = gated
                    && if lower_is_better { delta > threshold } else { delta < -threshold };
                if failed {
                    let (magnitude, direction) = if lower_is_better {
                        (delta * 100.0, "increase")
                    } else {
                        (-delta * 100.0, "regression")
                    };
                    failures.push(format!(
                        "{}/{}/{}: {magnitude:.1}% {direction} (max allowed {:.0}%)",
                        b.problem,
                        b.metric,
                        b.engine,
                        threshold * 100.0
                    ));
                }
                (c.value_per_sec, delta, failed)
            }
            None => {
                if gated {
                    failures.push(format!(
                        "{}/{}/{}: missing from current run",
                        b.problem, b.metric, b.engine
                    ));
                }
                (f64::NAN, f64::NAN, gated)
            }
        };
        rows.push(CompareRow {
            problem: b.problem.clone(),
            metric: b.metric.clone(),
            engine: b.engine.clone(),
            baseline: b.value_per_sec,
            current: current_v,
            delta,
            gated,
            failed,
        });
    }
    // Rows only the current run has (a bench added since the baseline was
    // recorded): shown as ungated "new" rows so the missing-baseline state
    // is visible instead of silently dropped — the fix is to refresh the
    // baseline.
    for c in &current.records {
        let known = baseline
            .records
            .iter()
            .any(|b| b.problem == c.problem && b.metric == c.metric && b.engine == c.engine);
        if !known {
            rows.push(CompareRow {
                problem: c.problem.clone(),
                metric: c.metric.clone(),
                engine: c.engine.clone(),
                baseline: f64::NAN,
                current: c.value_per_sec,
                delta: f64::NAN,
                gated: false,
                failed: false,
            });
        }
    }
    CompareReport { rows, failures, placeholder: baseline.placeholder }
}

/// Render the comparison as a markdown table (stdout + CI job summary).
pub fn markdown_table(report: &CompareReport, threshold: f64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## Throughput vs baseline (gate: >{:.0}% regression on batched rows)\n\n",
        threshold * 100.0
    ));
    if report.placeholder {
        out.push_str(
            "> **Baseline is a placeholder** — the gate reports but does not fail. \
             Refresh it on the reference machine: run `bench throughput --quick` \
             and `bench serve --quick`, merge both files' rows into \
             BENCH_baseline.json with per-row `\"bench\"` tags (do NOT overwrite \
             with one harness's file — that silently ungates the other), drop \
             the placeholder flag, commit.\n\n",
        );
    }
    out.push_str("| problem | metric | engine | baseline/s | current/s | Δ | status |\n");
    out.push_str("|---|---|---|---:|---:|---:|---|\n");
    for r in &report.rows {
        let status = if r.baseline.is_nan() {
            "new (ungated — refresh baseline)"
        } else if report.placeholder {
            // Placeholder baselines carry fake values (1s): per-row
            // "ok" would read as a real pass, so flag each row as
            // unbaselined instead and suppress the meaningless Δ.
            "unbaselined (placeholder — gate is a no-op)"
        } else if !r.gated {
            // Latency rows carry microseconds in the per-second column:
            // flag the unit and direction so +Δ% is not misread as a win.
            if r.metric.ends_with("_us") {
                "info (latency in µs — lower is better)"
            } else {
                "info"
            }
        } else if r.failed {
            "**FAIL**"
        } else {
            "ok"
        };
        let base = if r.baseline.is_nan() || report.placeholder {
            "—".to_string()
        } else {
            format!("{:.0}", r.baseline)
        };
        let (cur, delta) = if r.current.is_nan() {
            ("missing".to_string(), "—".to_string())
        } else if r.delta.is_nan() || report.placeholder {
            (format!("{:.0}", r.current), "—".to_string())
        } else {
            (format!("{:.0}", r.current), format!("{:+.1}%", r.delta * 100.0))
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |\n",
            r.problem, r.metric, r.engine, base, cur, delta, status
        ));
    }
    if !report.failures.is_empty() {
        out.push('\n');
        for f in &report.failures {
            out.push_str(&format!("- ❌ {f}\n"));
        }
    }
    out
}

/// CLI driver for `sdegrad bench compare`: read, diff, print, optionally
/// append to the job summary; returns the process exit code (0 pass,
/// 1 regression, 2 usage/io error). With `subset` (CLI `--subset
/// throughput|serve`), only that harness's rows participate on both
/// sides — how each CI job gates its own rows against the one merged
/// `BENCH_baseline.json`.
pub fn run_compare(
    baseline_path: &str,
    current_path: &str,
    threshold: f64,
    summary_path: Option<&str>,
    subset: Option<&str>,
) -> i32 {
    let read_parse = |path: &str| -> Result<BenchFile, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        parse_bench_json(&text).map_err(|e| format!("parsing {path}: {e}"))
    };
    let mut baseline = match read_parse(baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("bench compare: {e}");
            return 2;
        }
    };
    let mut current = match read_parse(current_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench compare: {e}");
            return 2;
        }
    };
    if let Some(s) = subset {
        baseline = filter_bench(&baseline, s);
        current = filter_bench(&current, s);
        if baseline.records.is_empty() && current.records.is_empty() {
            eprintln!("bench compare: no rows tagged bench={s:?} on either side");
            return 2;
        }
    }
    let report = compare_throughput(&baseline, &current, threshold);
    let table = markdown_table(&report, threshold);
    println!("{table}");
    if let Some(p) = summary_path {
        match std::fs::OpenOptions::new().create(true).append(true).open(p) {
            Ok(mut f) => {
                let _ = writeln!(f, "{table}");
            }
            Err(e) => eprintln!("bench compare: cannot append summary to {p}: {e}"),
        }
    }
    if report.placeholder {
        println!("baseline is a placeholder: gate reported, not enforced.");
        0
    } else if report.failures.is_empty() {
        println!("throughput gate: OK ({} rows compared).", report.rows.len());
        0
    } else {
        eprintln!("throughput gate: FAILED ({} regressions).", report.failures.len());
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quick sweep runs end-to-end, covers both engines on both
    /// problems, and leaves the JSON artifact behind.
    #[test]
    fn quick_throughput_produces_rows_and_artifact() {
        let rows = run_throughput(true);
        // 2 engines × (gbm solve + gbm grad + ckpt grad + nn solve) = 8
        // timing rows, plus the 2 observed checkpoint memory rows, plus
        // the 3 fast-tier rows (gbm solve + gbm grad + nn solve), plus
        // the 3 cached-tree rows (solve + grad + observed draws/step),
        // plus the pooled-ELBO row, the observed executor-overhead row,
        // and the observed tracing-overhead row.
        assert_eq!(rows.len(), 19);
        assert!(rows.iter().all(|r| r.value_per_sec.is_finite()));
        // Every row is strictly positive except tracing overhead, which
        // clamps timer noise to exactly 0.
        assert!(rows
            .iter()
            .filter(|r| r.metric != "trace_overhead_pct")
            .all(|r| r.value_per_sec > 0.0));
        let trace = rows
            .iter()
            .find(|r| r.problem == "tracing" && r.metric == "trace_overhead_pct")
            .expect("missing trace_overhead_pct row");
        assert!(trace.engine == "observed" && trace.value_per_sec >= 0.0);
        // The fast-tier rows are gate-shaped: engine "batched" with a
        // gated metric, under the `{problem}_fast` name.
        for (problem, metric) in [
            ("gbm_d10_fast", "paths_per_sec"),
            ("gbm_d10_fast", "grad_paths_per_sec"),
            ("neural_posterior_fast", "paths_per_sec"),
        ] {
            assert!(
                rows.iter().any(|r| r.problem == problem
                    && r.metric == metric
                    && r.engine == "batched"),
                "missing fast-tier row {problem}/{metric}"
            );
        }
        // The checkpointed row is gate-shaped (batched grad_paths_per_sec)
        // and its observability rows carry the schedule's memory trade.
        assert!(rows.iter().any(|r| r.problem == "gbm_d10_ckpt"
            && r.metric == "grad_paths_per_sec"
            && r.engine == "batched"));
        assert!(rows.iter().any(|r| r.metric == "peak_tape_bytes" && r.engine == "observed"));
        assert!(rows.iter().any(|r| r.metric == "recompute_nfe" && r.engine == "observed"));
        // The cached-tree rows are gate-shaped, and the observed draw
        // rate carries the amortized-O(1) contract (≤2 on a dyadic grid).
        for metric in ["paths_per_sec", "grad_paths_per_sec"] {
            assert!(
                rows.iter().any(|r| r.problem == "gbm_d10_cached"
                    && r.metric == metric
                    && r.engine == "batched"),
                "missing cached-tree row {metric}"
            );
        }
        let draws = rows
            .iter()
            .find(|r| r.metric == "bridge_calls_per_step" && r.engine == "observed")
            .expect("missing bridge_calls_per_step row");
        assert!(draws.value_per_sec <= 2.0, "cached draw rate {}", draws.value_per_sec);
        // The pooled-ELBO row is gate-shaped; the executor-overhead row
        // rides along ungated.
        assert!(rows.iter().any(|r| r.problem == "neural_posterior_pooled"
            && r.metric == "paths_per_sec"
            && r.engine == "batched"));
        assert!(rows
            .iter()
            .any(|r| r.problem == "executor" && r.metric == "overhead_us" && r.engine == "observed"));
        let json = std::fs::read_to_string("BENCH_throughput.json").expect("artifact written");
        assert!(json.contains("\"bench\": \"throughput\""));
        assert!(json.contains("grad_paths_per_sec"));
        // The artifact we write must parse back through the gate's
        // scanner (compare consumes exactly this format).
        let parsed = parse_bench_json(&json).expect("artifact parses");
        assert!(!parsed.placeholder);
        assert_eq!(parsed.records.len(), rows.len());
        for (rec, row) in parsed.records.iter().zip(&rows) {
            assert_eq!(rec.problem, row.problem);
            assert_eq!(rec.metric, row.metric);
            assert_eq!(rec.engine, row.engine);
        }
    }

    /// The baseline writer's output must round-trip through the gate's
    /// parser with per-row bench tags intact (what `--subset` keys on)
    /// and must never carry the placeholder flag.
    #[test]
    fn baseline_writer_round_trips_with_per_row_tags() {
        let tp = [ThroughputRow {
            problem: "gbm_d10",
            metric: "paths_per_sec",
            engine: "batched",
            paths: 256,
            steps: 200,
            value_per_sec: 1234.5,
        }];
        let sv = [ThroughputRow {
            problem: "serve_elbo",
            metric: "req_per_sec",
            engine: "batched",
            paths: 80,
            steps: 12,
            value_per_sec: 321.0,
        }];
        let path = std::env::temp_dir().join("sdegrad_baseline_writer_test.json");
        let path = path.to_str().unwrap();
        let parts: [(&str, &[ThroughputRow]); 2] = [("throughput", &tp), ("serve", &sv)];
        write_baseline_json(path, true, &parts).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let parsed = parse_bench_json(&text).expect("baseline parses");
        assert!(!parsed.placeholder);
        assert_eq!(parsed.records.len(), 2);
        assert_eq!(parsed.records[0].bench, "throughput");
        assert_eq!(parsed.records[1].bench, "serve");
        assert_eq!(filter_bench(&parsed, "serve").records.len(), 1);
        let _ = std::fs::remove_file(path);
    }

    fn bench_json(rows: &[(&str, &str, &str, f64)], placeholder: bool) -> String {
        let mut s = String::from("{\n  \"bench\": \"throughput\",\n  \"quick\": true,\n");
        if placeholder {
            s.push_str("  \"placeholder\": true,\n");
        }
        s.push_str("  \"results\": [\n");
        for (i, (p, m, e, v)) in rows.iter().enumerate() {
            let comma = if i + 1 == rows.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"problem\": \"{p}\", \"metric\": \"{m}\", \"engine\": \"{e}\", \
                 \"paths\": 256, \"steps\": 200, \"value_per_sec\": {v}}}{comma}\n"
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    #[test]
    fn compare_passes_within_threshold() {
        let base = parse_bench_json(&bench_json(
            &[
                ("gbm_d10", "paths_per_sec", "batched", 1000.0),
                ("gbm_d10", "grad_paths_per_sec", "batched", 500.0),
                ("gbm_d10", "paths_per_sec", "per_path", 800.0),
            ],
            false,
        ))
        .unwrap();
        // 10% slower: inside the 25% budget.
        let cur = parse_bench_json(&bench_json(
            &[
                ("gbm_d10", "paths_per_sec", "batched", 900.0),
                ("gbm_d10", "grad_paths_per_sec", "batched", 460.0),
                ("gbm_d10", "paths_per_sec", "per_path", 100.0), // info row: never gates
            ],
            false,
        ))
        .unwrap();
        let report = compare_throughput(&base, &cur, 0.25);
        assert!(report.passed(), "failures: {:?}", report.failures);
        assert_eq!(report.rows.len(), 3);
        assert!(report.rows.iter().filter(|r| r.gated).count() == 2);
        let md = markdown_table(&report, 0.25);
        assert!(md.contains("| gbm_d10 | paths_per_sec | batched |"), "{md}");
    }

    /// The acceptance check: an injected >25% synthetic regression on a
    /// gated row must fail the gate (this is what fails the CI
    /// `throughput` job).
    #[test]
    fn compare_fails_on_injected_regression() {
        let base = parse_bench_json(&bench_json(
            &[
                ("gbm_d10", "paths_per_sec", "batched", 1000.0),
                ("neural_posterior", "paths_per_sec", "batched", 300.0),
            ],
            false,
        ))
        .unwrap();
        let cur = parse_bench_json(&bench_json(
            &[
                ("gbm_d10", "paths_per_sec", "batched", 700.0), // −30%
                ("neural_posterior", "paths_per_sec", "batched", 310.0),
            ],
            false,
        ))
        .unwrap();
        let report = compare_throughput(&base, &cur, 0.25);
        assert!(!report.passed());
        assert_eq!(report.failures.len(), 1);
        assert!(report.failures[0].contains("gbm_d10"), "{:?}", report.failures);
        assert!(markdown_table(&report, 0.25).contains("**FAIL**"));
        // Exactly at −25%: passes (strictly-greater gate).
        let cur_edge = parse_bench_json(&bench_json(
            &[
                ("gbm_d10", "paths_per_sec", "batched", 750.0),
                ("neural_posterior", "paths_per_sec", "batched", 300.0),
            ],
            false,
        ))
        .unwrap();
        assert!(compare_throughput(&base, &cur_edge, 0.25).passed());
    }

    /// The serving load harness runs end-to-end (server on an ephemeral
    /// port, concurrent clients, open-loop overload episode, responses
    /// asserted against the scalar oracle inside) and leaves a
    /// gate-parsable artifact behind.
    #[test]
    fn quick_serve_bench_produces_gated_rows_and_artifact() {
        let rows = run_serve_bench(true, ExecConfig::default());
        // 2 endpoints × (req/sec + p50 + p99) closed loop, plus the 4
        // open-loop rows (p99 + shed_rate gated, p50 + offered observed).
        assert_eq!(rows.len(), 10);
        assert!(rows.iter().all(|r| r.value_per_sec.is_finite() && r.value_per_sec > 0.0));
        assert_eq!(
            rows.iter().filter(|r| r.metric == "req_per_sec" && r.engine == "batched").count(),
            2
        );
        for metric in ["serve_p99_ms", "shed_rate"] {
            assert!(
                rows.iter().any(|r| r.problem == "serve_open_loop"
                    && r.metric == metric
                    && r.engine == "batched"),
                "missing open-loop row {metric}"
            );
        }
        let json = std::fs::read_to_string("BENCH_serve.json").expect("artifact written");
        let parsed = parse_bench_json(&json).expect("artifact parses");
        assert!(!parsed.placeholder);
        assert_eq!(parsed.records.len(), rows.len());
        assert!(parsed.records.iter().all(|r| r.bench == "serve"), "file-level tag applies");
        // The gate considers serve req/sec + open-loop p99/shed-rate rows
        // gated rows; self-compare passes (lower-is-better rows at parity).
        let report = compare_throughput(&parsed, &parsed, 0.25);
        assert_eq!(report.rows.iter().filter(|r| r.gated).count(), 4);
        assert!(report.passed());
    }

    /// Lower-is-better rows gate on INCREASES: a p99 that doubles fails,
    /// a p99 that halves passes, and a zero-baseline shed rate gates on
    /// absolute excess instead of a blown-up ratio.
    #[test]
    fn lower_is_better_rows_gate_on_increase() {
        let base = parse_bench_json(&bench_json(
            &[
                ("serve_open_loop", "serve_p99_ms", "batched", 10.0),
                ("serve_open_loop", "shed_rate", "batched", 0.0),
            ],
            false,
        ))
        .unwrap();
        // p99 doubled: fails with an "increase" message.
        let slow = parse_bench_json(&bench_json(
            &[
                ("serve_open_loop", "serve_p99_ms", "batched", 20.0),
                ("serve_open_loop", "shed_rate", "batched", 0.0),
            ],
            false,
        ))
        .unwrap();
        let report = compare_throughput(&base, &slow, 0.25);
        assert!(!report.passed());
        assert_eq!(report.failures.len(), 1);
        assert!(report.failures[0].contains("increase"), "{:?}", report.failures);
        // p99 halved: an improvement, not a failure.
        let fast = parse_bench_json(&bench_json(
            &[
                ("serve_open_loop", "serve_p99_ms", "batched", 5.0),
                ("serve_open_loop", "shed_rate", "batched", 0.0),
            ],
            false,
        ))
        .unwrap();
        assert!(compare_throughput(&base, &fast, 0.25).passed());
        // Zero baseline: shed rate creeping to 0.2 is within the 0.25
        // absolute budget; 0.3 is over it.
        let shed_some = parse_bench_json(&bench_json(
            &[
                ("serve_open_loop", "serve_p99_ms", "batched", 10.0),
                ("serve_open_loop", "shed_rate", "batched", 0.2),
            ],
            false,
        ))
        .unwrap();
        assert!(compare_throughput(&base, &shed_some, 0.25).passed());
        let shed_lots = parse_bench_json(&bench_json(
            &[
                ("serve_open_loop", "serve_p99_ms", "batched", 10.0),
                ("serve_open_loop", "shed_rate", "batched", 0.3),
            ],
            false,
        ))
        .unwrap();
        assert!(!compare_throughput(&base, &shed_lots, 0.25).passed());
    }

    #[test]
    fn req_per_sec_regressions_fail_the_gate_and_subset_filters() {
        // A merged baseline: per-record bench tags, one row per harness.
        let merged = r#"{
  "bench": "baseline",
  "quick": true,
  "results": [
    {"bench": "throughput", "problem": "gbm_d10", "metric": "paths_per_sec", "engine": "batched", "paths": 256, "steps": 200, "value_per_sec": 1000},
    {"bench": "serve", "problem": "serve_simulate", "metric": "req_per_sec", "engine": "batched", "paths": 80, "steps": 12, "value_per_sec": 500},
    {"bench": "serve", "problem": "serve_simulate", "metric": "p99_us", "engine": "observed", "paths": 80, "steps": 12, "value_per_sec": 900}
  ]
}"#;
        let baseline = parse_bench_json(merged).unwrap();
        assert_eq!(baseline.records[0].bench, "throughput");
        assert_eq!(baseline.records[1].bench, "serve");

        // Subset "serve" drops the throughput row, so a serve-only
        // current file does not read as "missing gbm_d10".
        let serve_only = filter_bench(&baseline, "serve");
        assert_eq!(serve_only.records.len(), 2);
        let current = parse_bench_json(
            r#"{
  "bench": "serve",
  "quick": true,
  "results": [
    {"problem": "serve_simulate", "metric": "req_per_sec", "engine": "batched", "paths": 80, "steps": 12, "value_per_sec": 300},
    {"problem": "serve_simulate", "metric": "p99_us", "engine": "observed", "paths": 80, "steps": 12, "value_per_sec": 2000}
  ]
}"#,
        )
        .unwrap();
        // −40% req/sec: fails; the latency row is informational only.
        let report = compare_throughput(&serve_only, &current, 0.25);
        assert!(!report.passed());
        assert_eq!(report.failures.len(), 1);
        assert!(report.failures[0].contains("serve_simulate/req_per_sec"));
        // Within budget passes.
        let ok = parse_bench_json(
            r#"{
  "bench": "serve",
  "quick": true,
  "results": [
    {"problem": "serve_simulate", "metric": "req_per_sec", "engine": "batched", "paths": 80, "steps": 12, "value_per_sec": 450},
    {"problem": "serve_simulate", "metric": "p99_us", "engine": "observed", "paths": 80, "steps": 12, "value_per_sec": 950}
  ]
}"#,
        )
        .unwrap();
        assert!(compare_throughput(&serve_only, &ok, 0.25).passed());
    }

    #[test]
    fn compare_fails_on_missing_gated_row_and_skips_placeholder() {
        let base = parse_bench_json(&bench_json(
            &[("gbm_d10", "grad_paths_per_sec", "batched", 500.0)],
            false,
        ))
        .unwrap();
        let cur = parse_bench_json(&bench_json(
            &[("gbm_d10", "paths_per_sec", "batched", 999.0)],
            false,
        ))
        .unwrap();
        let report = compare_throughput(&base, &cur, 0.25);
        assert!(!report.passed());
        assert!(report.failures[0].contains("missing"));
        // The current-only row is surfaced as an ungated "new" row rather
        // than silently dropped.
        assert!(
            report.rows.iter().any(|r| r.baseline.is_nan() && r.metric == "paths_per_sec"),
            "current-only row not surfaced"
        );
        assert!(markdown_table(&report, 0.25).contains("refresh baseline"));

        // A placeholder baseline reports but never fails.
        let base_ph = parse_bench_json(&bench_json(
            &[("gbm_d10", "grad_paths_per_sec", "batched", 500.0)],
            true,
        ))
        .unwrap();
        assert!(base_ph.placeholder);
        let report_ph = compare_throughput(&base_ph, &cur, 0.25);
        assert!(report_ph.passed());
        let table_ph = markdown_table(&report_ph, 0.25);
        assert!(table_ph.contains("placeholder"));
        // Every baselined row is flagged unbaselined — no per-row "ok"
        // that could be misread as a real pass against fake values.
        assert!(
            table_ph.contains("unbaselined"),
            "placeholder rows not flagged:\n{table_ph}"
        );
        assert!(
            !table_ph.contains("| ok |"),
            "placeholder row rendered as ok:\n{table_ph}"
        );
    }
}
