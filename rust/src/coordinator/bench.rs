//! `sdegrad bench throughput` — multi-path throughput of the batched SoA
//! execution engine vs the per-path (thread-per-path) engine.
//!
//! Measures **paths/sec** (forward solves) and **grad-paths/sec**
//! (stochastic-adjoint gradients) on two workloads:
//!
//! * the 10-d replicated GBM of §7.1 (cheap coefficients — measures
//!   engine overhead: dispatch, noise, stepping), and
//! * a neural-drift SDE (the latent posterior with MLP drift/diffusion —
//!   measures the batched matrix–matrix win on net-bound dynamics).
//!
//! Both engines solve the *same problems from the same seeds* and are
//! bit-identical path-for-path (asserted here on every run), so the
//! numbers compare pure execution strategy. Results are printed as a
//! table and written to `BENCH_throughput.json` (hand-rolled JSON; the
//! crate set has no serde) for the CI artifact trajectory.

use crate::adjoint::AdjointConfig;
use crate::api::{
    sensitivity_batch, sensitivity_batch_per_path, solve_batch, solve_batch_local,
    solve_batch_per_path, SdeProblem, SensAlg, SolveOptions, StepControl,
};
use crate::latent::{LatentSdeConfig, LatentSdeModel, PosteriorSde};
use crate::metrics::writer::{json_num, json_str};
use crate::metrics::Stopwatch;
use crate::prng::PrngKey;
use crate::sde::problems::{sample_experiment_setup, Example1};
use crate::sde::{BatchSdeVjp, ReplicatedSde};
use crate::solvers::Method;
use std::io::Write;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub problem: &'static str,
    pub metric: &'static str,
    pub engine: &'static str,
    pub paths: usize,
    pub steps: usize,
    pub value_per_sec: f64,
}

fn time_best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    // Best-of-N wall clock (throughput benches want the least-noisy run;
    // one warmup rep is included and discarded).
    let mut best = f64::INFINITY;
    f();
    for _ in 0..reps {
        let sw = Stopwatch::new();
        std::hint::black_box(f());
        best = best.min(sw.elapsed_s());
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn run_problem<S>(
    rows: &mut Vec<ThroughputRow>,
    name: &'static str,
    prob: &SdeProblem<'_, S>,
    method: Method,
    n_paths: usize,
    n_steps: usize,
    reps: usize,
    with_grad: bool,
) where
    S: BatchSdeVjp + Sync + ?Sized,
{
    let root = PrngKey::from_seed(0x7140);
    let replicates = prob.replicates(root, n_paths);
    let opts = SolveOptions::fixed(method, n_steps);

    // Correctness gate: the two engines must agree bit-for-bit before
    // their times are worth comparing.
    let batched = solve_batch(&replicates, &opts);
    let per_path = solve_batch_per_path(&replicates, &opts);
    for (a, b) in batched.iter().zip(&per_path) {
        assert_eq!(a.states, b.states, "engines diverged on {name}");
    }

    let t_batched = time_best_of(reps, || solve_batch(&replicates, &opts)[0].final_state()[0]);
    let t_scalar =
        time_best_of(reps, || solve_batch_per_path(&replicates, &opts)[0].final_state()[0]);
    for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
        rows.push(ThroughputRow {
            problem: name,
            metric: "paths_per_sec",
            engine,
            paths: n_paths,
            steps: n_steps,
            value_per_sec: n_paths as f64 / secs,
        });
    }

    if with_grad {
        let alg = SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: method,
            ..Default::default()
        });
        let step = StepControl::Steps(n_steps);
        let g_batched = sensitivity_batch(&replicates, &alg, step);
        let g_per_path = sensitivity_batch_per_path(&replicates, &alg, step);
        for (a, b) in g_batched.iter().zip(&g_per_path) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "gradient engines diverged on {name}");
        }
        let t_batched = time_best_of(reps, || {
            sensitivity_batch(&replicates, &alg, step)[0].as_ref().unwrap().dtheta[0]
        });
        let t_scalar = time_best_of(reps, || {
            sensitivity_batch_per_path(&replicates, &alg, step)[0].as_ref().unwrap().dtheta[0]
        });
        for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
            rows.push(ThroughputRow {
                problem: name,
                metric: "grad_paths_per_sec",
                engine,
                paths: n_paths,
                steps: n_steps,
                value_per_sec: n_paths as f64 / secs,
            });
        }
    }
}

/// Run the throughput sweep; prints a table and writes
/// `BENCH_throughput.json`. `quick` shrinks paths/steps for CI smoke
/// runs.
pub fn run_throughput(quick: bool) -> Vec<ThroughputRow> {
    super::repro::headline("Throughput: batched SoA engine vs per-path engine");
    let (n_paths, n_steps, reps) = if quick { (256, 200, 3) } else { (2048, 1000, 5) };
    let mut rows = Vec::new();

    // 1. Replicated GBM, d = 10 (§7.1's system).
    let dim = 10;
    let gbm = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    run_problem(
        &mut rows,
        "gbm_d10",
        &prob,
        Method::MilsteinIto,
        n_paths,
        n_steps,
        reps,
        true,
    );

    // 2. Neural-drift SDE: the latent posterior (MLP drift + per-dim
    // diffusion nets) — the workload where batched net evaluation pays.
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 3,
        latent_dim: 4,
        context_dim: 1,
        hidden: 64,
        diff_hidden: 16,
        enc_hidden: 16,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(4));
    let post = PosteriorSde::new(&model);
    let mut theta_full = params[..post.sde_param_len()].to_vec();
    theta_full.push(0.3); // static context slot
    let aug = crate::sde::Sde::state_dim(&post);
    let y0 = vec![0.1; aug];
    // PosteriorSde carries interior-mutable scratch (not Sync), so both
    // engines run single-threaded here: batched kernel vs sequential
    // scalar solves — a pure engine comparison at equal thread counts.
    let (nn_paths, nn_steps) = if quick { (64, 50) } else { (256, 200) };
    let nn_prob = SdeProblem::new(&post, &y0, (0.0, 0.5)).params(&theta_full);
    let nn_replicates = nn_prob.replicates(PrngKey::from_seed(0x7141), nn_paths);
    let nn_opts = SolveOptions::fixed(Method::Heun, nn_steps);
    let batched = solve_batch_local(&nn_replicates, &nn_opts);
    let sequential: Vec<_> = nn_replicates.iter().map(|p| p.solve(&nn_opts)).collect();
    for (a, b) in batched.iter().zip(&sequential) {
        assert_eq!(a.states, b.states, "engines diverged on neural_posterior");
    }
    let t_batched =
        time_best_of(reps, || solve_batch_local(&nn_replicates, &nn_opts)[0].final_state()[0]);
    let t_scalar = time_best_of(reps, || {
        nn_replicates.iter().map(|p| p.solve(&nn_opts).final_state()[0]).sum()
    });
    for (engine, secs) in [("batched", t_batched), ("per_path", t_scalar)] {
        rows.push(ThroughputRow {
            problem: "neural_posterior",
            metric: "paths_per_sec",
            engine,
            paths: nn_paths,
            steps: nn_steps,
            value_per_sec: nn_paths as f64 / secs,
        });
    }

    println!(
        "{:<18} {:>20} {:>10} {:>7} {:>7} {:>14}",
        "problem", "metric", "engine", "paths", "steps", "per_sec"
    );
    for r in &rows {
        println!(
            "{:<18} {:>20} {:>10} {:>7} {:>7} {:>14.0}",
            r.problem, r.metric, r.engine, r.paths, r.steps, r.value_per_sec
        );
    }
    for metric in ["paths_per_sec", "grad_paths_per_sec"] {
        for problem in ["gbm_d10", "neural_posterior"] {
            let get = |engine: &str| {
                rows.iter()
                    .find(|r| r.metric == metric && r.problem == problem && r.engine == engine)
                    .map(|r| r.value_per_sec)
            };
            if let (Some(b), Some(s)) = (get("batched"), get("per_path")) {
                println!("speedup {problem}/{metric}: {:.2}x", b / s);
            }
        }
    }

    write_json("BENCH_throughput.json", quick, &rows).expect("writing BENCH_throughput.json");
    println!("(JSON: BENCH_throughput.json)");
    rows
}

fn write_json(path: &str, quick: bool, rows: &[ThroughputRow]) -> std::io::Result<()> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "{{")?;
    writeln!(out, "  \"bench\": \"throughput\",")?;
    writeln!(out, "  \"quick\": {quick},")?;
    writeln!(out, "  \"root_seed\": {},", 0x7140)?;
    writeln!(out, "  \"results\": [")?;
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            out,
            "    {{\"problem\": {}, \"metric\": {}, \"engine\": {}, \"paths\": {}, \
             \"steps\": {}, \"value_per_sec\": {}}}{comma}",
            json_str(r.problem),
            json_str(r.metric),
            json_str(r.engine),
            r.paths,
            r.steps,
            json_num(r.value_per_sec),
        )?;
    }
    writeln!(out, "  ]")?;
    writeln!(out, "}}")?;
    out.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quick sweep runs end-to-end, covers both engines on both
    /// problems, and leaves the JSON artifact behind.
    #[test]
    fn quick_throughput_produces_rows_and_artifact() {
        let rows = run_throughput(true);
        // 2 engines × (gbm solve + gbm grad + nn solve) = 6 rows.
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.value_per_sec.is_finite() && r.value_per_sec > 0.0));
        let json = std::fs::read_to_string("BENCH_throughput.json").expect("artifact written");
        assert!(json.contains("\"bench\": \"throughput\""));
        assert!(json.contains("grad_paths_per_sec"));
    }
}
