//! Training configuration + a tiny `--key value` argument parser (clap is
//! not in the vendored crate set — DESIGN.md §3).

use std::collections::HashMap;

use crate::runtime::ExecConfig;
use crate::sde::KernelTier;

/// Trainer hyperparameters (§7.3 defaults: Adam @ 1e-2, 0.999 decay,
/// KL annealing, ≤400 iterations).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub iters: u64,
    pub batch_size: usize,
    pub lr: f64,
    pub lr_decay: f64,
    pub kl_weight: f64,
    pub kl_anneal_iters: u64,
    pub substeps: usize,
    pub grad_clip: f64,
    pub seed: u64,
    /// Validate every this many iterations (0 = never).
    pub val_every: u64,
    /// Posterior samples S per sequence in the minibatch ELBO-gradient
    /// estimate (the batched engine advances all M·S paths together;
    /// paper training uses 1, larger S tightens the per-iteration
    /// estimate).
    pub elbo_samples: usize,
    /// Execution configuration ([`ExecConfig`]). `exec.tier` is the
    /// kernel tier for the batched engine (`--tier exact|fast`): `Exact`
    /// keeps the bit-identical-to-scalar float stream; `Fast` trades that
    /// for throughput (tolerance-validated kernels). The tier is part of
    /// the schedule fingerprint: a checkpoint refuses to resume under the
    /// other tier. `exec.threads` is the worker count (`--workers`;
    /// `None` follows the global `--threads` > `SDEGRAD_THREADS` >
    /// `available_parallelism` chain) — never part of the fingerprint,
    /// since worker count never changes a float.
    pub exec: ExecConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 400,
            batch_size: 16,
            lr: 0.01,
            lr_decay: 0.999,
            kl_weight: 1.0,
            kl_anneal_iters: 50,
            substeps: 5,
            grad_clip: 10.0,
            seed: 0,
            val_every: 20,
            elbo_samples: 1,
            exec: ExecConfig::default(),
        }
    }
}

impl TrainConfig {
    /// The effective worker count for the batched minibatch engine
    /// (`exec.threads`, or the process-wide chain when unpinned).
    pub fn n_workers(&self) -> usize {
        self.exec.worker_count()
    }
}

/// The process-wide worker count — delegates to
/// [`crate::runtime::worker_count`], the single knob every parallel
/// surface shares (`--threads` flag > `SDEGRAD_THREADS` env >
/// `available_parallelism`). The old per-subsystem cap at 8 is gone:
/// the persistent pool parks idle workers, so extra width no longer
/// costs per-call spawn overhead.
pub fn num_threads() -> usize {
    crate::runtime::worker_count()
}

/// Parse `--key value` style arguments into a map. Flags without values
/// get `"true"`.
pub fn parse_args(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Fetch + parse helper.
pub fn arg<T: std::str::FromStr>(map: &HashMap<String, String>, key: &str, default: T) -> T {
    map.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl TrainConfig {
    /// Override fields from parsed CLI args.
    pub fn from_args(map: &HashMap<String, String>) -> TrainConfig {
        let d = TrainConfig::default();
        TrainConfig {
            iters: arg(map, "iters", d.iters),
            batch_size: arg(map, "batch", d.batch_size),
            lr: arg(map, "lr", d.lr),
            lr_decay: arg(map, "lr-decay", d.lr_decay),
            kl_weight: arg(map, "kl", d.kl_weight),
            kl_anneal_iters: arg(map, "kl-anneal", d.kl_anneal_iters),
            substeps: arg(map, "substeps", d.substeps),
            grad_clip: arg(map, "clip", d.grad_clip),
            seed: arg(map, "seed", d.seed),
            val_every: arg(map, "val-every", d.val_every),
            elbo_samples: arg(map, "samples", d.elbo_samples),
            exec: {
                let mut exec = d.exec;
                if let Some(w) = map.get("workers").and_then(|v| v.parse().ok()) {
                    exec.threads = Some(w);
                }
                exec.tier = map
                    .get("tier")
                    .and_then(|v| KernelTier::parse(v))
                    .unwrap_or(exec.tier);
                exec
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_key_values_and_flags() {
        let m = parse_args(&strs(&["--iters", "100", "--quick", "--lr", "0.02"]));
        assert_eq!(m["iters"], "100");
        assert_eq!(m["quick"], "true");
        assert_eq!(m["lr"], "0.02");
    }

    #[test]
    fn config_from_args_overrides() {
        let m = parse_args(&strs(&["--iters", "7", "--batch", "3"]));
        let cfg = TrainConfig::from_args(&m);
        assert_eq!(cfg.iters, 7);
        assert_eq!(cfg.batch_size, 3);
        assert_eq!(cfg.lr, TrainConfig::default().lr);
    }

    #[test]
    fn arg_fallback_on_garbage() {
        let m = parse_args(&strs(&["--iters", "not-a-number"]));
        assert_eq!(arg(&m, "iters", 42u64), 42);
    }

    #[test]
    fn tier_from_args() {
        let m = parse_args(&strs(&["--tier", "fast"]));
        assert_eq!(TrainConfig::from_args(&m).exec.tier, KernelTier::Fast);
        let m = parse_args(&strs(&["--tier", "bogus"]));
        assert_eq!(TrainConfig::from_args(&m).exec.tier, KernelTier::Exact);
        let m = parse_args(&strs(&[]));
        assert_eq!(TrainConfig::from_args(&m).exec.tier, KernelTier::Exact);
    }

    #[test]
    fn workers_from_args_pin_exec_threads() {
        let m = parse_args(&strs(&["--workers", "3"]));
        let cfg = TrainConfig::from_args(&m);
        assert_eq!(cfg.exec.threads, Some(3));
        assert_eq!(cfg.n_workers(), 3);
        let m = parse_args(&strs(&[]));
        let cfg = TrainConfig::from_args(&m);
        assert_eq!(cfg.exec.threads, None);
        assert_eq!(cfg.n_workers(), num_threads().max(1));
    }
}
