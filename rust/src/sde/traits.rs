//! Core SDE traits.

use crate::brownian::BrownianMotion;

/// Which stochastic calculus the (drift, diffusion) pair is written in.
///
/// For diagonal noise the two are interconvertible by the drift correction
/// `b_strat = b_ito − ½ σ ∂σ/∂z` (componentwise). The solvers and the
/// adjoint operate natively in Stratonovich form (§2.4: its symmetry is
/// what makes "running the SDE backwards" well defined — see Fig 2);
/// Itô systems are integrated with Itô schemes or converted.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Calculus {
    Ito,
    Stratonovich,
}

/// A parameterized d-dimensional diagonal-noise SDE.
///
/// State `z ∈ R^d`, parameters `θ ∈ R^p`, noise `W ∈ R^d`, with
/// `dZ_i = b_i(z,t,θ) dt + σ_i(z_i,t,θ) dW_i`.
pub trait Sde {
    /// State dimension d.
    fn state_dim(&self) -> usize;
    /// Parameter dimension p.
    fn param_dim(&self) -> usize;
    /// Calculus in which drift/diffusion are expressed.
    fn calculus(&self) -> Calculus;

    /// Drift `b(z, t, θ)` into `out` (length d).
    fn drift(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]);

    /// Diagonal diffusion `σ(z, t, θ)` into `out` (length d).
    fn diffusion(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]);

    /// `∂σ_i/∂z_i` into `out` (length d). Needed for Milstein schemes and
    /// Itô↔Stratonovich conversion.
    fn diffusion_dz_diag(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]);

    /// Stratonovich drift regardless of native calculus:
    /// `b_strat = b − ½ σ σ'` when native form is Itô.
    ///
    /// `scratch` must hold at least `2·d` floats (σ and σ′ are evaluated
    /// into it). The adjoint calls this once per backward stage, so the
    /// buffer is caller-provided rather than allocated per call.
    fn drift_stratonovich(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        out: &mut [f64],
        scratch: &mut [f64],
    ) {
        self.drift(t, z, theta, out);
        if self.calculus() == Calculus::Ito {
            let d = self.state_dim();
            let (sig, rest) = scratch.split_at_mut(d);
            let dsig = &mut rest[..d];
            self.diffusion(t, z, theta, sig);
            self.diffusion_dz_diag(t, z, theta, dsig);
            for i in 0..d {
                out[i] -= 0.5 * sig[i] * dsig[i];
            }
        }
    }
}

/// Vector-Jacobian products for the stochastic adjoint (Algorithm 2).
///
/// All VJPs are *accumulating*: they add into `out_*` so the augmented
/// backward dynamics can sum drift and diffusion contributions without
/// temporaries. VJPs are taken of the functions **in the trait object's
/// native calculus**; the adjoint machinery requests Stratonovich-form
/// VJPs via [`SdeVjp::drift_vjp_stratonovich`].
pub trait SdeVjp: Sde {
    /// Accumulate `aᵀ ∂b/∂z` into `out_z` (len d) and `aᵀ ∂b/∂θ` into
    /// `out_theta` (len p).
    fn drift_vjp(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    );

    /// Accumulate `aᵀ ∂σ/∂z` and `aᵀ ∂σ/∂θ`. With diagonal σ (σ_i depends
    /// on z_i), `(aᵀ∂σ/∂z)_i = a_i ∂σ_i/∂z_i`.
    fn diffusion_vjp(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    );

    /// Whether [`SdeVjp::ito_correction_vjp`] is implemented. Implementors
    /// that provide the correction VJP must override this to `true`;
    /// `crate::api::SdeProblem` consults it *before* integrating so an
    /// Itô-native system without the correction VJP surfaces as a
    /// [`Result`] error at problem validation instead of a mid-solve
    /// panic.
    fn has_ito_correction_vjp(&self) -> bool {
        false
    }

    /// Validate that this system can serve a Stratonovich-form drift VJP
    /// (i.e. the stochastic adjoint): Itô-native systems must implement
    /// [`SdeVjp::ito_correction_vjp`]. Called by the problem API before
    /// any integration starts.
    fn check_adjoint_compatible(&self) -> Result<(), &'static str> {
        if self.calculus() == Calculus::Ito && !self.has_ito_correction_vjp() {
            Err("ito_correction_vjp not provided: express this SDE in \
                 Stratonovich form or supply the correction VJP")
        } else {
            Ok(())
        }
    }

    /// VJP of the Itô→Stratonovich correction term `c(z) = ½ σ σ'`
    /// (i.e. accumulate `aᵀ ∂c/∂z`, `aᵀ ∂c/∂θ`). Only required when the
    /// native calculus is Itô *and* the adjoint is used; systems written
    /// natively in Stratonovich form may leave this unimplemented (and
    /// keep [`SdeVjp::has_ito_correction_vjp`] at `false`, which the
    /// problem API turns into a construction-time error).
    fn ito_correction_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _theta: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        // Unreachable through crate::api::SdeProblem, which performs
        // construction-time validation via check_adjoint_compatible.
        panic!(
            "ito_correction_vjp not provided: express this SDE in \
             Stratonovich form or supply the correction VJP (and override \
             has_ito_correction_vjp) — the crate::api::SdeProblem entry \
             points surface this as a ProblemError before integrating"
        );
    }

    /// Accumulate the Stratonovich-form drift VJP: native drift VJP minus
    /// the correction VJP when the native calculus is Itô.
    ///
    /// `scratch` must hold at least `d` floats (the negated adjoint is
    /// staged there — this runs four times per backward Heun step, so the
    /// buffer is caller-provided rather than allocated per call).
    #[allow(clippy::too_many_arguments)]
    fn drift_vjp_stratonovich(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        scratch: &mut [f64],
    ) {
        self.drift_vjp(t, z, theta, a, out_z, out_theta);
        if self.calculus() == Calculus::Ito {
            // out += aᵀ ∂(−c)/∂· ⇒ accumulate with negated adjoint.
            let neg = &mut scratch[..a.len()];
            for (n, v) in neg.iter_mut().zip(a) {
                *n = -v;
            }
            self.ito_correction_vjp(t, z, theta, &scratch[..a.len()], out_z, out_theta);
        }
    }
}

/// An SDE with an exact pathwise strong solution: given query access to
/// the *same* realized Brownian path that drove a numerical solve, the
/// implementor reconstructs the true terminal state (and the pathwise
/// gradients of the §7.1 loss `L = Σ_i X_{t1}^{(i)}`) with no
/// discretization error in the step size.
///
/// This is the oracle side of the [`crate::convergence`] subsystem: the
/// solver under test and the oracle consume one Brownian source, so their
/// difference is pure discretization error and the empirical order of
/// convergence (§5) can be measured against it.
///
/// Implementations may query `bm` at times the solver never visited
/// (e.g. [`crate::sde::ou::OrnsteinUhlenbeck`] evaluates time-weighted
/// Riemann integrals of the path on a fine grid via
/// [`crate::brownian::quadrature`]); both Brownian sources interpolate
/// such queries with the correct bridge law, so the oracle stays
/// consistent with whatever the solver revealed.
pub trait ExactSolution: Sde {
    /// Exact strong solution `X_{t1}` (length `state_dim`) for the
    /// problem started at `z0` at `span.0`, driven by `bm`. The path is
    /// read relative to `bm`'s value at `span.0`.
    fn exact_state(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        out: &mut [f64],
    );

    /// Exact pathwise gradients of the summed terminal loss
    /// `L = Σ_i X_{t1}^{(i)}` holding the realized path fixed:
    /// `grad_z0` (length `state_dim`) and `grad_theta` (length
    /// `param_dim`) are *overwritten*.
    fn exact_sum_gradients(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        grad_z0: &mut [f64],
        grad_theta: &mut [f64],
    );
}

/// A scalar (1-d state, 1-d noise) parameterized SDE with everything the
/// numerical studies need spelled out analytically: partial derivatives for
/// VJPs, second derivatives for Milstein, closed-form strong solution and
/// its pathwise parameter gradients.
///
/// §7.1 replicates each scalar problem 10× with independent per-dimension
/// parameters; [`super::problems::ReplicatedSde`] lifts a `ScalarSde` to
/// that d-dimensional system.
pub trait ScalarSde: Send + Sync {
    /// Number of parameters k of the scalar problem (excluding x0).
    fn nparams(&self) -> usize;
    /// Calculus of the (drift, diffusion) pair below.
    fn calculus(&self) -> Calculus;

    fn drift(&self, t: f64, x: f64, th: &[f64]) -> f64;
    fn diffusion(&self, t: f64, x: f64, th: &[f64]) -> f64;

    /// ∂b/∂x, ∂σ/∂x, ∂²σ/∂x².
    fn drift_dx(&self, t: f64, x: f64, th: &[f64]) -> f64;
    fn diffusion_dx(&self, t: f64, x: f64, th: &[f64]) -> f64;
    fn diffusion_dxx(&self, t: f64, x: f64, th: &[f64]) -> f64;

    /// ∂b/∂θ_j and ∂σ/∂θ_j into `out` (length nparams).
    fn drift_dtheta(&self, t: f64, x: f64, th: &[f64], out: &mut [f64]);
    fn diffusion_dtheta(&self, t: f64, x: f64, th: &[f64], out: &mut [f64]);

    /// ∂²σ/∂x∂θ_j into `out` (needed for the Itô-correction VJP).
    fn diffusion_dx_dtheta(&self, t: f64, x: f64, th: &[f64], out: &mut [f64]);

    /// Closed-form strong solution `X_t` given `W_t = w` (all three paper
    /// problems depend on the path only through `W_t`).
    fn analytic_solution(&self, t: f64, x0: f64, th: &[f64], w: f64) -> f64;

    /// Pathwise gradients of the closed-form solution holding the Brownian
    /// path fixed: `(∂X_t/∂x0, ∂X_t/∂θ_j …)` — `out` has length
    /// `1 + nparams`, x0-gradient first.
    fn analytic_gradients(&self, t: f64, x0: f64, th: &[f64], w: f64, out: &mut [f64]);

    /// Human-readable name for harness output.
    fn name(&self) -> &'static str;
}
