//! SDE abstractions and concrete systems.
//!
//! The core trait family:
//! * [`Sde`] — a parameterized diagonal-noise SDE `dZ = b(z,t,θ) dt +
//!   σ(z,t,θ) dW` in a declared calculus (Itô or Stratonovich).
//! * [`SdeVjp`] — adds the vector-Jacobian products the stochastic adjoint
//!   consumes: `a ↦ aᵀ∂b/∂z, aᵀ∂b/∂θ, aᵀ∂σ/∂z, aᵀ∂σ/∂θ`.
//!
//! Diagonal noise is assumed throughout (m = d, `σ_i` multiplies `dW_i`),
//! matching every experiment in the paper; per App. 9.4 this makes the
//! adjoint's noise commutative so strong-order-1.0 schemes apply without
//! Lévy-area simulation. As in the paper's architectures (App. 9.9/9.11,
//! "each small net for a single dimension"), `σ_i` depends on `z_i` only.
//!
//! Concrete systems:
//! * [`problems`] — the three closed-form test problems of §7.1/App. 9.7
//!   (as 1-d `ScalarSde`s plus the paper's 10× replication wrapper), with
//!   analytic solutions and analytic pathwise gradients.
//! * [`lorenz`] — the stochastic Lorenz attractor (App. 9.9.2).
//! * [`ou`] — Ornstein–Uhlenbeck (closed-form moments; extra test system).
//!
//! Systems with a closed-form strong solution additionally implement
//! [`ExactSolution`] — the pathwise oracle the [`crate::convergence`]
//! subsystem measures empirical convergence orders against.

pub mod batch;
pub mod func;
pub mod lorenz;
pub mod ou;
pub mod problems;
pub mod traits;

pub use batch::{BatchSde, BatchSdeVjp, KernelTier};
pub use func::{ForwardFunc, SdeFunc};
pub use problems::{ReplicatedSde, ScalarProblem};
pub use traits::{Calculus, ExactSolution, ScalarSde, Sde, SdeVjp};
