//! Stochastic Lorenz attractor (App. 9.9.2) — the data-generating process
//! for the Fig 6/8 experiments, and an `Sde` in its own right so harnesses
//! can also differentiate through it.
//!
//! ```text
//! dX = σ(Y − X) dt       + α_x dW_1
//! dY = (X(ρ − Z) − Y) dt + α_y dW_2
//! dZ = (XY − βZ) dt      + α_z dW_3
//! ```
//!
//! Additive noise, so Itô = Stratonovich. θ = [σ, ρ, β, α_x, α_y, α_z].

use super::batch::{BatchSde, BatchSdeVjp};
use super::traits::{Calculus, Sde, SdeVjp};

/// The stochastic Lorenz system. Parameters live in θ (see module docs).
#[derive(Clone, Copy, Debug, Default)]
pub struct StochasticLorenz;

/// The paper's ground-truth parameter values: σ=10, ρ=28, β=8/3,
/// α = (0.15, 0.15, 0.15).
pub fn paper_theta() -> Vec<f64> {
    vec![10.0, 28.0, 8.0 / 3.0, 0.15, 0.15, 0.15]
}

impl Sde for StochasticLorenz {
    fn state_dim(&self) -> usize {
        3
    }
    fn param_dim(&self) -> usize {
        6
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito // additive noise: Itô == Stratonovich
    }
    fn drift(&self, _t: f64, z: &[f64], th: &[f64], out: &mut [f64]) {
        let (x, y, zz) = (z[0], z[1], z[2]);
        let (sigma, rho, beta) = (th[0], th[1], th[2]);
        out[0] = sigma * (y - x);
        out[1] = x * (rho - zz) - y;
        out[2] = x * y - beta * zz;
    }
    fn diffusion(&self, _t: f64, _z: &[f64], th: &[f64], out: &mut [f64]) {
        out[0] = th[3];
        out[1] = th[4];
        out[2] = th[5];
    }
    fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _th: &[f64], out: &mut [f64]) {
        out.fill(0.0);
    }
}

impl SdeVjp for StochasticLorenz {
    fn drift_vjp(
        &self,
        _t: f64,
        z: &[f64],
        th: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let (x, y, zz) = (z[0], z[1], z[2]);
        let (sigma, rho, beta) = (th[0], th[1], th[2]);
        // Jᵀa with J = ∂b/∂z:
        //   J = [ [−σ, σ, 0], [ρ−z, −1, −x], [y, x, −β] ]
        out_z[0] += -sigma * a[0] + (rho - zz) * a[1] + y * a[2];
        out_z[1] += sigma * a[0] - a[1] + x * a[2];
        out_z[2] += -x * a[1] - beta * a[2];
        // ∂b/∂θ: b0 depends on σ; b1 on ρ; b2 on β.
        out_theta[0] += (y - x) * a[0];
        out_theta[1] += x * a[1];
        out_theta[2] += -zz * a[2];
        // α's do not enter the drift.
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        a: &[f64],
        _out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        // σ_i = α_i: ∂σ/∂z = 0; ∂σ_i/∂α_i = 1.
        out_theta[3] += a[0];
        out_theta[4] += a[1];
        out_theta[5] += a[2];
    }

    fn has_ito_correction_vjp(&self) -> bool {
        true
    }

    fn ito_correction_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        // Additive noise: c = ½σσ' ≡ 0, so the VJP accumulates nothing.
    }
}

// Loop-based batch evaluation (d = 3 with fully coupled drift rows — the
// default per-row kernels are already the natural shape here).
impl BatchSde for StochasticLorenz {}
impl BatchSdeVjp for StochasticLorenz {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_vjp_matches_finite_difference() {
        let sys = StochasticLorenz;
        let z = [1.2, -0.7, 14.0];
        let th = paper_theta();
        let a = [0.3, -1.1, 0.9];
        let eps = 1e-6;

        let mut vz = vec![0.0; 3];
        let mut vth = vec![0.0; 6];
        sys.drift_vjp(0.0, &z, &th, &a, &mut vz, &mut vth);

        let mut hi = [0.0; 3];
        let mut lo = [0.0; 3];
        for i in 0..3 {
            let mut zp = z;
            zp[i] += eps;
            sys.drift(0.0, &zp, &th, &mut hi);
            zp[i] -= 2.0 * eps;
            sys.drift(0.0, &zp, &th, &mut lo);
            let fd: f64 = (0..3).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vz[i]).abs() < 1e-5, "z[{i}]: {fd} vs {}", vz[i]);
        }
        for j in 0..6 {
            let mut tp = th.clone();
            tp[j] += eps;
            sys.drift(0.0, &z, &tp, &mut hi);
            tp[j] -= 2.0 * eps;
            sys.drift(0.0, &z, &tp, &mut lo);
            let fd: f64 = (0..3).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vth[j]).abs() < 1e-5, "θ[{j}]: {fd} vs {}", vth[j]);
        }
    }

    #[test]
    fn diffusion_vjp_matches_finite_difference() {
        let sys = StochasticLorenz;
        let z = [1.2, -0.7, 14.0];
        let th = paper_theta();
        let a = [0.3, -1.1, 0.9];
        let mut vz = vec![0.0; 3];
        let mut vth = vec![0.0; 6];
        sys.diffusion_vjp(0.0, &z, &th, &a, &mut vz, &mut vth);
        assert_eq!(vz, vec![0.0; 3]);
        assert_eq!(&vth[3..], &[0.3, -1.1, 0.9]);
    }
}
