//! Ornstein–Uhlenbeck process — an additive-noise system with closed-form
//! transition moments, used as an extra verification target for solvers
//! (weak-convergence tests) and as the §8 example of an SDE that is also a
//! Gaussian process.
//!
//! `dX = κ(μ − X) dt + s dW`, θ = [κ, μ, s].
//! Transition: `X_t | X_0 = x0 ~ N(μ + (x0 − μ)e^{−κt}, s²(1 − e^{−2κt})/(2κ))`.

use super::batch::{BatchSde, BatchSdeVjp};
use super::traits::{Calculus, ExactSolution, Sde, SdeVjp};
use crate::brownian::{weighted_path_integrals, BrownianMotion};

/// Quadrature resolution of the pathwise exact solution (see
/// [`OrnsteinUhlenbeck::with_quadrature_intervals`]).
const DEFAULT_QUAD_INTERVALS: usize = 1 << 14;

/// Scalar OU process replicated over `dim` dimensions with shared θ.
#[derive(Clone, Copy, Debug)]
pub struct OrnsteinUhlenbeck {
    dim: usize,
    quad_intervals: usize,
}

impl OrnsteinUhlenbeck {
    pub fn new(dim: usize) -> Self {
        OrnsteinUhlenbeck { dim, quad_intervals: DEFAULT_QUAD_INTERVALS }
    }

    /// Override the quadrature grid used by the [`ExactSolution`] oracle
    /// (trapezoid intervals for the path integrals; the oracle's pathwise
    /// error is `O(1/n)`). The default (2¹⁴) keeps the oracle error a few
    /// percent of the finest solver rung the convergence harness uses.
    pub fn with_quadrature_intervals(mut self, n: usize) -> Self {
        assert!(n > 0, "quadrature needs at least one interval");
        self.quad_intervals = n;
        self
    }

    /// Per-dimension stochastic integrals of the variation-of-constants
    /// solution, reconstructed from the realized path:
    /// `I_i = ∫ e^{−κ(t1−u)} dW_i` and `J_i = ∫ (t1−u) e^{−κ(t1−u)} dW_i`
    /// (each returned vector has length `dim`). Both are reduced to
    /// Riemann integrals of the path by parts and evaluated with
    /// [`weighted_path_integrals`] on one shared sweep.
    fn path_integrals(
        &self,
        span: (f64, f64),
        kappa: f64,
        bm: &mut dyn BrownianMotion,
    ) -> (Vec<f64>, Vec<f64>) {
        let (t0, t1) = span;
        let d = self.dim;
        // ∫ e^{−κ(t1−u)} dW = W̃(t1) − κ·∫ e^{−κ(t1−u)} W̃(u) du
        // ∫ (t1−u) e^{−κ(t1−u)} dW = ∫ e^{−κ(t1−u)} (1 − κ(t1−u)) W̃(u) du
        let ker_a = |u: f64| (-kappa * (t1 - u)).exp();
        let ker_b = |u: f64| (-kappa * (t1 - u)).exp() * (1.0 - kappa * (t1 - u));
        let kernels: [&dyn Fn(f64) -> f64; 2] = [&ker_a, &ker_b];
        let mut ab = vec![0.0; 2 * d];
        weighted_path_integrals(bm, t0, t1, self.quad_intervals, &kernels, &mut ab);
        let mut w_end = vec![0.0; d];
        let mut w_start = vec![0.0; d];
        bm.sample_into(t0, &mut w_start);
        bm.sample_into(t1, &mut w_end);
        let mut i_int = vec![0.0; d];
        let mut j_int = vec![0.0; d];
        for i in 0..d {
            i_int[i] = (w_end[i] - w_start[i]) - kappa * ab[i];
            j_int[i] = ab[d + i];
        }
        (i_int, j_int)
    }

    /// Closed-form mean of `X_t | x0` per dimension.
    pub fn mean(&self, t: f64, x0: f64, th: &[f64]) -> f64 {
        let (kappa, mu) = (th[0], th[1]);
        mu + (x0 - mu) * (-kappa * t).exp()
    }

    /// Closed-form variance of `X_t | x0`.
    pub fn variance(&self, t: f64, th: &[f64]) -> f64 {
        let (kappa, s) = (th[0], th[2]);
        s * s * (1.0 - (-2.0 * kappa * t).exp()) / (2.0 * kappa)
    }
}

impl Sde for OrnsteinUhlenbeck {
    fn state_dim(&self) -> usize {
        self.dim
    }
    fn param_dim(&self) -> usize {
        3
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito // additive noise: Itô == Stratonovich
    }
    fn drift(&self, _t: f64, z: &[f64], th: &[f64], out: &mut [f64]) {
        let (kappa, mu) = (th[0], th[1]);
        for i in 0..self.dim {
            out[i] = kappa * (mu - z[i]);
        }
    }
    fn diffusion(&self, _t: f64, _z: &[f64], th: &[f64], out: &mut [f64]) {
        out.fill(th[2]);
    }
    fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _th: &[f64], out: &mut [f64]) {
        out.fill(0.0);
    }
}

impl SdeVjp for OrnsteinUhlenbeck {
    fn drift_vjp(
        &self,
        _t: f64,
        z: &[f64],
        th: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let (kappa, mu) = (th[0], th[1]);
        for i in 0..self.dim {
            out_z[i] += -kappa * a[i];
            out_theta[0] += (mu - z[i]) * a[i];
            out_theta[1] += kappa * a[i];
        }
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        a: &[f64],
        _out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        out_theta[2] += a.iter().sum::<f64>();
    }

    fn has_ito_correction_vjp(&self) -> bool {
        true
    }

    fn ito_correction_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        // Additive noise: c = ½σσ' ≡ 0, so the VJP accumulates nothing.
    }
}

/// Hand-batched kernels: the OU coefficients are affine with shared θ, so
/// the batch evaluation is one flat sweep over the `[B×d]` buffer (no
/// per-row dispatch; identical floats cell-for-cell).
impl BatchSde for OrnsteinUhlenbeck {
    fn drift_batch(&self, _t: f64, z: &[f64], th: &[f64], out: &mut [f64]) {
        let (kappa, mu) = (th[0], th[1]);
        for (o, zi) in out.iter_mut().zip(z) {
            *o = kappa * (mu - zi);
        }
    }

    fn diffusion_batch(&self, _t: f64, _z: &[f64], th: &[f64], out: &mut [f64]) {
        out.fill(th[2]);
    }

    fn diffusion_dz_diag_batch(&self, _t: f64, _z: &[f64], _th: &[f64], out: &mut [f64]) {
        out.fill(0.0);
    }

    /// Fast tier: both coefficients in one flat sweep (the diffusion is a
    /// constant fill fused into the same pass).
    fn drift_diffusion_batch_fast(
        &self,
        _t: f64,
        z: &[f64],
        th: &[f64],
        f_out: &mut [f64],
        g_out: &mut [f64],
    ) {
        let (kappa, mu, s) = (th[0], th[1], th[2]);
        for ((f, g), zi) in f_out.iter_mut().zip(g_out.iter_mut()).zip(z) {
            *f = kappa * (mu - zi);
            *g = s;
        }
    }

    /// Fast tier: additive noise means `½σσ′ ≡ 0`, so the Stratonovich
    /// drift is the drift — one flat sweep, no σ/σ′ staging.
    fn drift_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        th: &[f64],
        out: &mut [f64],
        _scratch: &mut [f64],
    ) {
        self.drift_batch(t, z, th, out);
    }
}

/// Fast-tier VJP sweeps: the θ-side accumulations are plain row
/// reductions, free to reassociate into per-path partial sums (the exact
/// defaults pin the scalar engine's accumulation order instead).
impl BatchSdeVjp for OrnsteinUhlenbeck {
    fn drift_vjp_batch_fast(
        &self,
        _t: f64,
        z: &[f64],
        th: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.dim;
        let (kappa, mu) = (th[0], th[1]);
        let bsz = z.len() / d;
        for b in 0..bsz {
            let mut gk = 0.0;
            let mut ga = 0.0;
            for i in 0..d {
                let idx = b * d + i;
                out_z[idx] += -kappa * a[idx];
                gk += (mu - z[idx]) * a[idx];
                ga += a[idx];
            }
            out_theta[b * 3] += gk;
            out_theta[b * 3 + 1] += kappa * ga;
        }
    }

    fn diffusion_vjp_batch_fast(
        &self,
        _t: f64,
        z: &[f64],
        _th: &[f64],
        a: &[f64],
        _out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.dim;
        let bsz = z.len() / d;
        for b in 0..bsz {
            out_theta[b * 3 + 2] += a[b * d..(b + 1) * d].iter().sum::<f64>();
        }
    }

    fn ito_correction_vjp_batch_fast(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        // Additive noise: c ≡ 0.
    }

    fn drift_vjp_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        th: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        _scratch: &mut [f64],
    ) {
        self.drift_vjp_batch_fast(t, z, th, a, out_z, out_theta);
    }
}

/// Pathwise exact solution via variation of constants,
/// `X_{t1} = μ + (x0 − μ)e^{−κT} + s ∫ e^{−κ(t1−u)} dW_u`, with the
/// stochastic integral reconstructed from the realized path by
/// integration by parts + fine trapezoid quadrature (error `O(1/n)` in
/// the quadrature grid, independent of any solver step size). Gradients
/// of `L = Σ_i X_{t1}^{(i)}` follow by differentiating the same formula:
/// `∂/∂κ` brings in `J = ∫ (t1−u) e^{−κ(t1−u)} dW_u = −∂I/∂κ`.
impl ExactSolution for OrnsteinUhlenbeck {
    fn exact_state(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        out: &mut [f64],
    ) {
        let (kappa, mu, s) = (theta[0], theta[1], theta[2]);
        let tt = span.1 - span.0;
        let e = (-kappa * tt).exp();
        let (i_int, _) = self.path_integrals(span, kappa, bm);
        for i in 0..self.dim {
            out[i] = mu + (z0[i] - mu) * e + s * i_int[i];
        }
    }

    fn exact_sum_gradients(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        grad_z0: &mut [f64],
        grad_theta: &mut [f64],
    ) {
        let (kappa, mu, s) = (theta[0], theta[1], theta[2]);
        let tt = span.1 - span.0;
        let e = (-kappa * tt).exp();
        let (i_int, j_int) = self.path_integrals(span, kappa, bm);
        grad_z0.fill(e);
        grad_theta.fill(0.0);
        for i in 0..self.dim {
            grad_theta[0] += -tt * (z0[i] - mu) * e - s * j_int[i];
            grad_theta[1] += 1.0 - e;
            grad_theta[2] += i_int[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::BrownianPath;
    use crate::prng::PrngKey;

    #[test]
    fn moments_limits() {
        let ou = OrnsteinUhlenbeck::new(1);
        let th = [2.0, 1.5, 0.5];
        // t → ∞: mean → μ, var → s²/(2κ).
        assert!((ou.mean(50.0, -3.0, &th) - 1.5).abs() < 1e-12);
        assert!((ou.variance(50.0, &th) - 0.0625).abs() < 1e-12);
        // t = 0: mean = x0, var = 0.
        assert_eq!(ou.mean(0.0, -3.0, &th), -3.0);
        assert_eq!(ou.variance(0.0, &th), 0.0);
    }

    /// The quadrature-based exact solution must agree with a very fine
    /// Euler–Maruyama solve on the *same* stored path (EM is exact for the
    /// OU drift up to O(δ) with a tiny constant at δ = 2⁻¹⁴).
    #[test]
    fn exact_state_matches_fine_euler_on_same_path() {
        let ou = OrnsteinUhlenbeck::new(2);
        let th = [1.2, 0.3, 0.5];
        let x0 = [0.9, 0.4];
        let n = 1usize << 14;
        let mut bm = BrownianPath::new(PrngKey::from_seed(77), 2, 0.0, 1.0);

        // Fine EM sweep (reveals the path on the fine grid first).
        let h = 1.0 / n as f64;
        let mut x = x0;
        let mut wa = [0.0; 2];
        let mut wb = [0.0; 2];
        bm.sample_into(0.0, &mut wa);
        for k in 0..n {
            let tn = if k + 1 == n { 1.0 } else { h * (k + 1) as f64 };
            bm.sample_into(tn, &mut wb);
            for i in 0..2 {
                let dw = wb[i] - wa[i];
                x[i] += th[0] * (th[1] - x[i]) * h + th[2] * dw;
            }
            wa = wb;
        }

        let mut exact = [0.0; 2];
        ou.exact_state((0.0, 1.0), &x0, &th, &mut bm, &mut exact);
        for i in 0..2 {
            assert!(
                (exact[i] - x[i]).abs() < 2e-3,
                "dim {i}: oracle {} vs fine EM {}",
                exact[i],
                x[i]
            );
        }
    }

    /// The oracle's pathwise gradients must be the derivatives of the
    /// oracle's own state: central differences on a fixed path (the
    /// virtual tree is a pure function, so every evaluation replays the
    /// identical path).
    #[test]
    fn exact_gradients_match_finite_difference_of_exact_state() {
        use crate::brownian::VirtualBrownianTree;
        let ou = OrnsteinUhlenbeck::new(2).with_quadrature_intervals(1 << 12);
        let th = [1.2, 0.3, 0.5];
        let x0 = [0.9, 0.4];
        let span = (0.0, 1.0);
        let key = PrngKey::from_seed(78);

        let loss = |x0: &[f64; 2], th: &[f64; 3]| -> f64 {
            let mut bm = VirtualBrownianTree::new(key, 2, span.0, span.1, 1e-12);
            let mut out = [0.0; 2];
            ou.exact_state(span, x0, th, &mut bm, &mut out);
            out.iter().sum()
        };

        let mut gz0 = [0.0; 2];
        let mut gth = [0.0; 3];
        let mut bm = VirtualBrownianTree::new(key, 2, span.0, span.1, 1e-12);
        ou.exact_sum_gradients(span, &x0, &th, &mut bm, &mut gz0, &mut gth);

        let eps = 1e-5;
        for j in 0..3 {
            let mut tp = th;
            tp[j] += eps;
            let hi = loss(&x0, &tp);
            tp[j] -= 2.0 * eps;
            let lo = loss(&x0, &tp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - gth[j]).abs() < 1e-6, "θ[{j}]: fd {fd} vs oracle {}", gth[j]);
        }
        for i in 0..2 {
            let mut xp = x0;
            xp[i] += eps;
            let hi = loss(&xp, &th);
            xp[i] -= 2.0 * eps;
            let lo = loss(&xp, &th);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - gz0[i]).abs() < 1e-6, "x0[{i}]: fd {fd} vs oracle {}", gz0[i]);
        }
    }

    /// Across independent seeds the oracle's terminal state must follow
    /// the closed-form transition law N(mean, variance) — validates the
    /// integration-by-parts identity statistically.
    #[test]
    fn exact_state_follows_transition_law() {
        let ou = OrnsteinUhlenbeck::new(1).with_quadrature_intervals(256);
        let th = [1.5, 0.2, 0.6];
        let x0 = [1.1];
        let n_seeds = 4_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for seed in 0..n_seeds {
            let mut bm = BrownianPath::new(PrngKey::from_seed(40_000 + seed), 1, 0.0, 1.0);
            let mut out = [0.0];
            ou.exact_state((0.0, 1.0), &x0, &th, &mut bm, &mut out);
            sum += out[0];
            sumsq += out[0] * out[0];
        }
        let mean = sum / n_seeds as f64;
        let var = sumsq / n_seeds as f64 - mean * mean;
        let exact_mean = ou.mean(1.0, x0[0], &th);
        let exact_var = ou.variance(1.0, &th);
        assert!((mean - exact_mean).abs() < 0.02, "mean {mean} vs {exact_mean}");
        assert!((var - exact_var).abs() < 0.015, "var {var} vs {exact_var}");
    }

    #[test]
    fn vjp_finite_difference() {
        let ou = OrnsteinUhlenbeck::new(2);
        let z = [0.4, -1.0];
        let th = [2.0, 1.5, 0.5];
        let a = [1.0, -0.5];
        let eps = 1e-6;
        let mut vz = vec![0.0; 2];
        let mut vth = vec![0.0; 3];
        ou.drift_vjp(0.0, &z, &th, &a, &mut vz, &mut vth);
        let mut hi = [0.0; 2];
        let mut lo = [0.0; 2];
        for j in 0..3 {
            let mut tp = th;
            tp[j] += eps;
            ou.drift(0.0, &z, &tp, &mut hi);
            tp[j] -= 2.0 * eps;
            ou.drift(0.0, &z, &tp, &mut lo);
            let fd: f64 = (0..2).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vth[j]).abs() < 1e-6, "θ[{j}]");
        }
    }
}
