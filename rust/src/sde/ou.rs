//! Ornstein–Uhlenbeck process — an additive-noise system with closed-form
//! transition moments, used as an extra verification target for solvers
//! (weak-convergence tests) and as the §8 example of an SDE that is also a
//! Gaussian process.
//!
//! `dX = κ(μ − X) dt + s dW`, θ = [κ, μ, s].
//! Transition: `X_t | X_0 = x0 ~ N(μ + (x0 − μ)e^{−κt}, s²(1 − e^{−2κt})/(2κ))`.

use super::traits::{Calculus, Sde, SdeVjp};

/// Scalar OU process replicated over `dim` dimensions with shared θ.
#[derive(Clone, Copy, Debug)]
pub struct OrnsteinUhlenbeck {
    dim: usize,
}

impl OrnsteinUhlenbeck {
    pub fn new(dim: usize) -> Self {
        OrnsteinUhlenbeck { dim }
    }

    /// Closed-form mean of `X_t | x0` per dimension.
    pub fn mean(&self, t: f64, x0: f64, th: &[f64]) -> f64 {
        let (kappa, mu) = (th[0], th[1]);
        mu + (x0 - mu) * (-kappa * t).exp()
    }

    /// Closed-form variance of `X_t | x0`.
    pub fn variance(&self, t: f64, th: &[f64]) -> f64 {
        let (kappa, s) = (th[0], th[2]);
        s * s * (1.0 - (-2.0 * kappa * t).exp()) / (2.0 * kappa)
    }
}

impl Sde for OrnsteinUhlenbeck {
    fn state_dim(&self) -> usize {
        self.dim
    }
    fn param_dim(&self) -> usize {
        3
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito // additive noise: Itô == Stratonovich
    }
    fn drift(&self, _t: f64, z: &[f64], th: &[f64], out: &mut [f64]) {
        let (kappa, mu) = (th[0], th[1]);
        for i in 0..self.dim {
            out[i] = kappa * (mu - z[i]);
        }
    }
    fn diffusion(&self, _t: f64, _z: &[f64], th: &[f64], out: &mut [f64]) {
        out.fill(th[2]);
    }
    fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _th: &[f64], out: &mut [f64]) {
        out.fill(0.0);
    }
}

impl SdeVjp for OrnsteinUhlenbeck {
    fn drift_vjp(
        &self,
        _t: f64,
        z: &[f64],
        th: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let (kappa, mu) = (th[0], th[1]);
        for i in 0..self.dim {
            out_z[i] += -kappa * a[i];
            out_theta[0] += (mu - z[i]) * a[i];
            out_theta[1] += kappa * a[i];
        }
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        a: &[f64],
        _out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        out_theta[2] += a.iter().sum::<f64>();
    }

    fn has_ito_correction_vjp(&self) -> bool {
        true
    }

    fn ito_correction_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _th: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        // Additive noise: c = ½σσ' ≡ 0, so the VJP accumulates nothing.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_limits() {
        let ou = OrnsteinUhlenbeck::new(1);
        let th = [2.0, 1.5, 0.5];
        // t → ∞: mean → μ, var → s²/(2κ).
        assert!((ou.mean(50.0, -3.0, &th) - 1.5).abs() < 1e-12);
        assert!((ou.variance(50.0, &th) - 0.0625).abs() < 1e-12);
        // t = 0: mean = x0, var = 0.
        assert_eq!(ou.mean(0.0, -3.0, &th), -3.0);
        assert_eq!(ou.variance(0.0, &th), 0.0);
    }

    #[test]
    fn vjp_finite_difference() {
        let ou = OrnsteinUhlenbeck::new(2);
        let z = [0.4, -1.0];
        let th = [2.0, 1.5, 0.5];
        let a = [1.0, -0.5];
        let eps = 1e-6;
        let mut vz = vec![0.0; 2];
        let mut vth = vec![0.0; 3];
        ou.drift_vjp(0.0, &z, &th, &a, &mut vz, &mut vth);
        let mut hi = [0.0; 2];
        let mut lo = [0.0; 2];
        for j in 0..3 {
            let mut tp = th;
            tp[j] += eps;
            ou.drift(0.0, &z, &tp, &mut hi);
            tp[j] -= 2.0 * eps;
            ou.drift(0.0, &z, &tp, &mut lo);
            let fd: f64 = (0..2).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vth[j]).abs() < 1e-6, "θ[{j}]");
        }
    }
}
