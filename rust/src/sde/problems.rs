//! The closed-form test problems of §7.1 / Appendix 9.7 and the 10×
//! replication wrapper used by the paper's numerical studies.
//!
//! Each problem implements [`ScalarSde`] with hand-derived partials (for
//! VJPs and Milstein terms) and the closed-form strong solution with its
//! pathwise parameter gradients, which are the ground truth of Fig 5/7.
//!
//! Calculus conventions (derived via Itô's lemma from the stated analytic
//! solutions — note the paper's App. 9.7 has two typos which we correct and
//! document here):
//!
//! * **Example 1** (geometric Brownian motion): `dX = αX dt + βX dW` (Itô)
//!   with solution `X_t = x0·exp((α − β²/2)t + βW_t)`. (The appendix swaps
//!   α and β between the SDE and its solution; the SDE as printed is the
//!   one we use, and the solution above is the correct one for it.)
//! * **Example 2**: `dX = −p² sin(X)cos³(X) dt + p cos²(X) dW` (Itô), with
//!   solution `X_t = arctan(pW_t + tan(x0))`. (The appendix's `−(p²)²` is a
//!   typo: Itô's lemma on the printed solution yields the `−p²` drift.) In
//!   Stratonovich form the drift vanishes entirely — a sharp test of the
//!   Itô↔Stratonovich machinery.
//! * **Example 3**: `dX = (β/√(1+t) − X/(2(1+t))) dt + αβ/√(1+t) dW`,
//!   additive noise (Itô = Stratonovich), with solution
//!   `X_t = x0/√(1+t) + β(t + αW_t)/√(1+t)`.

use super::batch::{BatchSde, BatchSdeVjp};
use super::traits::{Calculus, ExactSolution, ScalarSde, Sde, SdeVjp};
use crate::brownian::BrownianMotion;

// ---------------------------------------------------------------------------
// Example 1: geometric Brownian motion. θ = [α, β].
// ---------------------------------------------------------------------------

/// `dX = αX dt + βX dW` (Itô).
#[derive(Clone, Copy, Debug, Default)]
pub struct Example1;

impl ScalarSde for Example1 {
    fn nparams(&self) -> usize {
        2
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito
    }
    fn drift(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        th[0] * x
    }
    fn diffusion(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        th[1] * x
    }
    fn drift_dx(&self, _t: f64, _x: f64, th: &[f64]) -> f64 {
        th[0]
    }
    fn diffusion_dx(&self, _t: f64, _x: f64, th: &[f64]) -> f64 {
        th[1]
    }
    fn diffusion_dxx(&self, _t: f64, _x: f64, _th: &[f64]) -> f64 {
        0.0
    }
    fn drift_dtheta(&self, _t: f64, x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = x;
        out[1] = 0.0;
    }
    fn diffusion_dtheta(&self, _t: f64, x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = 0.0;
        out[1] = x;
    }
    fn diffusion_dx_dtheta(&self, _t: f64, _x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = 0.0;
        out[1] = 1.0;
    }
    fn analytic_solution(&self, t: f64, x0: f64, th: &[f64], w: f64) -> f64 {
        let (alpha, beta) = (th[0], th[1]);
        x0 * ((alpha - 0.5 * beta * beta) * t + beta * w).exp()
    }
    fn analytic_gradients(&self, t: f64, x0: f64, th: &[f64], w: f64, out: &mut [f64]) {
        let xt = self.analytic_solution(t, x0, th, w);
        out[0] = xt / x0; // ∂X_t/∂x0
        out[1] = t * xt; // ∂X_t/∂α
        out[2] = (w - th[1] * t) * xt; // ∂X_t/∂β
    }
    fn name(&self) -> &'static str {
        "example1-gbm"
    }
}

// ---------------------------------------------------------------------------
// Example 2. θ = [p].
// ---------------------------------------------------------------------------

/// `dX = −p² sin(X)cos³(X) dt + p cos²(X) dW` (Itô); Stratonovich drift is
/// identically zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct Example2;

impl ScalarSde for Example2 {
    fn nparams(&self) -> usize {
        1
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito
    }
    fn drift(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        let p = th[0];
        -p * p * x.sin() * x.cos().powi(3)
    }
    fn diffusion(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        th[0] * x.cos().powi(2)
    }
    fn drift_dx(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        let p = th[0];
        let (s, c) = x.sin_cos();
        // d/dx [−p² s c³] = −p² (c⁴ − 3 s² c²)
        -p * p * (c.powi(4) - 3.0 * s * s * c * c)
    }
    fn diffusion_dx(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        let (s, c) = x.sin_cos();
        -2.0 * th[0] * s * c
    }
    fn diffusion_dxx(&self, _t: f64, x: f64, th: &[f64]) -> f64 {
        let (s, c) = x.sin_cos();
        -2.0 * th[0] * (c * c - s * s)
    }
    fn drift_dtheta(&self, _t: f64, x: f64, th: &[f64], out: &mut [f64]) {
        out[0] = -2.0 * th[0] * x.sin() * x.cos().powi(3);
    }
    fn diffusion_dtheta(&self, _t: f64, x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = x.cos().powi(2);
    }
    fn diffusion_dx_dtheta(&self, _t: f64, x: f64, _th: &[f64], out: &mut [f64]) {
        let (s, c) = x.sin_cos();
        out[0] = -2.0 * s * c;
    }
    fn analytic_solution(&self, _t: f64, x0: f64, th: &[f64], w: f64) -> f64 {
        (th[0] * w + x0.tan()).atan()
    }
    fn analytic_gradients(&self, _t: f64, x0: f64, th: &[f64], w: f64, out: &mut [f64]) {
        let u = th[0] * w + x0.tan();
        let denom = 1.0 + u * u;
        out[0] = (1.0 / x0.cos().powi(2)) / denom; // ∂/∂x0 = sec²(x0)/(1+u²)
        out[1] = w / denom; // ∂/∂p
    }
    fn name(&self) -> &'static str {
        "example2-tanh"
    }
}

// ---------------------------------------------------------------------------
// Example 3: additive time-dependent noise. θ = [α, β].
// ---------------------------------------------------------------------------

/// `dX = (β/√(1+t) − X/(2(1+t))) dt + αβ/√(1+t) dW` — additive noise, so
/// the Itô and Stratonovich forms coincide.
#[derive(Clone, Copy, Debug, Default)]
pub struct Example3;

impl ScalarSde for Example3 {
    fn nparams(&self) -> usize {
        2
    }
    fn calculus(&self) -> Calculus {
        // Additive noise: Itô == Stratonovich. Declared Itô so Itô
        // schemes apply directly (the Stratonovich correction is zero).
        Calculus::Ito
    }
    fn drift(&self, t: f64, x: f64, th: &[f64]) -> f64 {
        th[1] / (1.0 + t).sqrt() - x / (2.0 * (1.0 + t))
    }
    fn diffusion(&self, t: f64, _x: f64, th: &[f64]) -> f64 {
        th[0] * th[1] / (1.0 + t).sqrt()
    }
    fn drift_dx(&self, t: f64, _x: f64, _th: &[f64]) -> f64 {
        -1.0 / (2.0 * (1.0 + t))
    }
    fn diffusion_dx(&self, _t: f64, _x: f64, _th: &[f64]) -> f64 {
        0.0
    }
    fn diffusion_dxx(&self, _t: f64, _x: f64, _th: &[f64]) -> f64 {
        0.0
    }
    fn drift_dtheta(&self, t: f64, _x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = 0.0;
        out[1] = 1.0 / (1.0 + t).sqrt();
    }
    fn diffusion_dtheta(&self, t: f64, _x: f64, th: &[f64], out: &mut [f64]) {
        let root = (1.0 + t).sqrt();
        out[0] = th[1] / root;
        out[1] = th[0] / root;
    }
    fn diffusion_dx_dtheta(&self, _t: f64, _x: f64, _th: &[f64], out: &mut [f64]) {
        out[0] = 0.0;
        out[1] = 0.0;
    }
    fn analytic_solution(&self, t: f64, x0: f64, th: &[f64], w: f64) -> f64 {
        let root = (1.0 + t).sqrt();
        x0 / root + th[1] * (t + th[0] * w) / root
    }
    fn analytic_gradients(&self, t: f64, _x0: f64, th: &[f64], w: f64, out: &mut [f64]) {
        let root = (1.0 + t).sqrt();
        out[0] = 1.0 / root; // ∂/∂x0
        out[1] = th[1] * w / root; // ∂/∂α
        out[2] = (t + th[0] * w) / root; // ∂/∂β
    }
    fn name(&self) -> &'static str {
        "example3-additive"
    }
}

// ---------------------------------------------------------------------------
// Replication wrapper (§7.1: "duplicate the equation 10 times ... each
// dimension with its own parameter values").
// ---------------------------------------------------------------------------

/// Boxed scalar problem handle used by harnesses.
pub type ScalarProblem = Box<dyn ScalarSde>;

/// d independent copies of a scalar problem, each with its own parameter
/// block: `θ = [θ^(1) … θ^(d)]`, `θ^(i) ∈ R^k`. Diagonal noise: dimension i
/// is driven by `W_i` only.
pub struct ReplicatedSde<P: ScalarSde> {
    problem: P,
    dim: usize,
}

impl<P: ScalarSde> ReplicatedSde<P> {
    pub fn new(problem: P, dim: usize) -> Self {
        assert!(dim > 0);
        ReplicatedSde { problem, dim }
    }

    pub fn problem(&self) -> &P {
        &self.problem
    }

    #[inline]
    fn th<'a>(&self, theta: &'a [f64], i: usize) -> &'a [f64] {
        let k = self.problem.nparams();
        &theta[i * k..(i + 1) * k]
    }

    /// Closed-form solution for all dimensions given `W_T` per dimension.
    pub fn analytic_solution(&self, t: f64, x0: &[f64], theta: &[f64], w: &[f64], out: &mut [f64]) {
        for i in 0..self.dim {
            out[i] = self.problem.analytic_solution(t, x0[i], self.th(theta, i), w[i]);
        }
    }

    /// Pathwise gradient of the loss `L = Σ_i X_T^(i)` w.r.t. `(x0, θ)`:
    /// `grad_x0` has length d, `grad_theta` length d·k.
    pub fn analytic_loss_gradients(
        &self,
        t: f64,
        x0: &[f64],
        theta: &[f64],
        w: &[f64],
        grad_x0: &mut [f64],
        grad_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let mut buf = vec![0.0; 1 + k];
        for i in 0..self.dim {
            self.problem
                .analytic_gradients(t, x0[i], self.th(theta, i), w[i], &mut buf);
            grad_x0[i] = buf[0];
            grad_theta[i * k..(i + 1) * k].copy_from_slice(&buf[1..]);
        }
    }
}

impl<P: ScalarSde> Sde for ReplicatedSde<P> {
    fn state_dim(&self) -> usize {
        self.dim
    }
    fn param_dim(&self) -> usize {
        self.dim * self.problem.nparams()
    }
    fn calculus(&self) -> Calculus {
        self.problem.calculus()
    }
    fn drift(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        for i in 0..self.dim {
            out[i] = self.problem.drift(t, z[i], self.th(theta, i));
        }
    }
    fn diffusion(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        for i in 0..self.dim {
            out[i] = self.problem.diffusion(t, z[i], self.th(theta, i));
        }
    }
    fn diffusion_dz_diag(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        for i in 0..self.dim {
            out[i] = self.problem.diffusion_dx(t, z[i], self.th(theta, i));
        }
    }
}

impl<P: ScalarSde> SdeVjp for ReplicatedSde<P> {
    fn drift_vjp(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let mut dth = vec![0.0; k];
        for i in 0..self.dim {
            let th = self.th(theta, i);
            out_z[i] += a[i] * self.problem.drift_dx(t, z[i], th);
            self.problem.drift_dtheta(t, z[i], th, &mut dth);
            for j in 0..k {
                out_theta[i * k + j] += a[i] * dth[j];
            }
        }
    }

    fn diffusion_vjp(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let mut dth = vec![0.0; k];
        for i in 0..self.dim {
            let th = self.th(theta, i);
            out_z[i] += a[i] * self.problem.diffusion_dx(t, z[i], th);
            self.problem.diffusion_dtheta(t, z[i], th, &mut dth);
            for j in 0..k {
                out_theta[i * k + j] += a[i] * dth[j];
            }
        }
    }

    fn has_ito_correction_vjp(&self) -> bool {
        true
    }

    fn ito_correction_vjp(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        // c_i = ½ σ_i σ_i'.
        // ∂c_i/∂z_i = ½ (σ_i' σ_i' + σ_i σ_i'')
        // ∂c_i/∂θ_j = ½ (∂σ_i/∂θ_j · σ_i' + σ_i · ∂σ_i'/∂θ_j)
        let k = self.problem.nparams();
        let mut dsig_dth = vec![0.0; k];
        let mut dsigx_dth = vec![0.0; k];
        for i in 0..self.dim {
            let th = self.th(theta, i);
            let sig = self.problem.diffusion(t, z[i], th);
            let sig_x = self.problem.diffusion_dx(t, z[i], th);
            let sig_xx = self.problem.diffusion_dxx(t, z[i], th);
            out_z[i] += a[i] * 0.5 * (sig_x * sig_x + sig * sig_xx);
            self.problem.diffusion_dtheta(t, z[i], th, &mut dsig_dth);
            self.problem.diffusion_dx_dtheta(t, z[i], th, &mut dsigx_dth);
            for j in 0..k {
                out_theta[i * k + j] += a[i] * 0.5 * (dsig_dth[j] * sig_x + sig * dsigx_dth[j]);
            }
        }
    }
}

/// Hand-batched evaluation: the replicated system's coefficients factor
/// per dimension, so the batch kernels iterate dimension-major — the
/// per-dimension parameter slice is taken **once** and reused across all
/// B paths (stride-d column walk over the `[B×d]` buffer) instead of
/// being re-sliced B·d times as the loop-based default would. Values are
/// bit-identical to the default (same scalar ops per `(b, i)` cell).
impl<P: ScalarSde> BatchSde for ReplicatedSde<P> {
    fn drift_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.dim;
        let bsz = z.len() / d;
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                out[b * d + i] = self.problem.drift(t, z[b * d + i], th);
            }
        }
    }

    fn diffusion_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.dim;
        let bsz = z.len() / d;
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                out[b * d + i] = self.problem.diffusion(t, z[b * d + i], th);
            }
        }
    }

    fn diffusion_dz_diag_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.dim;
        let bsz = z.len() / d;
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                out[b * d + i] = self.problem.diffusion_dx(t, z[b * d + i], th);
            }
        }
    }

    /// Fast tier: one fused dimension-major sweep produces both
    /// coefficients — each `z` cell is loaded once and the per-dimension
    /// parameter slice stays hot for drift *and* diffusion.
    fn drift_diffusion_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        f_out: &mut [f64],
        g_out: &mut [f64],
    ) {
        let d = self.dim;
        let bsz = z.len() / d;
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                let zi = z[b * d + i];
                f_out[b * d + i] = self.problem.drift(t, zi, th);
                g_out[b * d + i] = self.problem.diffusion(t, zi, th);
            }
        }
    }

    /// Fast tier: the Stratonovich drift as one flat per-cell expression
    /// (`b − ½σσ′` for native-Itô problems) instead of the row-loop with
    /// σ/σ′ staging — no scratch traffic, one pass over `z`.
    fn drift_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        out: &mut [f64],
        _scratch: &mut [f64],
    ) {
        let d = self.dim;
        let bsz = z.len() / d;
        let ito = self.problem.calculus() == Calculus::Ito;
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                let zi = z[b * d + i];
                let mut v = self.problem.drift(t, zi, th);
                if ito {
                    v -= 0.5 * self.problem.diffusion(t, zi, th) * self.problem.diffusion_dx(t, zi, th);
                }
                out[b * d + i] = v;
            }
        }
    }
}

impl<P: ScalarSde> ReplicatedSde<P> {
    /// Shared body of the fast Itô-correction VJP: accumulate
    /// `sign · a ⊙ ∂c/∂·` with `c_i = ½σ_iσ_i′`, dimension-major with the
    /// per-dimension derivative scratch hoisted out of the path loop.
    fn ito_correction_vjp_fast_signed(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        sign: f64,
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let d = self.dim;
        let bsz = z.len() / d;
        let mut dsig_dth = vec![0.0; k];
        let mut dsigx_dth = vec![0.0; k];
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                let zi = z[b * d + i];
                let ai = sign * a[b * d + i];
                let sig = self.problem.diffusion(t, zi, th);
                let sig_x = self.problem.diffusion_dx(t, zi, th);
                let sig_xx = self.problem.diffusion_dxx(t, zi, th);
                out_z[b * d + i] += ai * 0.5 * (sig_x * sig_x + sig * sig_xx);
                self.problem.diffusion_dtheta(t, zi, th, &mut dsig_dth);
                self.problem.diffusion_dx_dtheta(t, zi, th, &mut dsigx_dth);
                let row = &mut out_theta[b * d * k + i * k..b * d * k + (i + 1) * k];
                for j in 0..k {
                    row[j] += ai * 0.5 * (dsig_dth[j] * sig_x + sig * dsigx_dth[j]);
                }
            }
        }
    }
}

/// Fast-tier VJP sweeps: dimension-major with the per-dimension
/// `∂·/∂θ` scratch hoisted out of the path loop — the loop-based exact
/// defaults pay one scratch allocation *per path* per call; these pay one
/// per call.
impl<P: ScalarSde> BatchSdeVjp for ReplicatedSde<P> {
    fn drift_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let d = self.dim;
        let bsz = z.len() / d;
        let mut dth = vec![0.0; k];
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                let zi = z[b * d + i];
                let ai = a[b * d + i];
                out_z[b * d + i] += ai * self.problem.drift_dx(t, zi, th);
                self.problem.drift_dtheta(t, zi, th, &mut dth);
                let row = &mut out_theta[b * d * k + i * k..b * d * k + (i + 1) * k];
                for j in 0..k {
                    row[j] += ai * dth[j];
                }
            }
        }
    }

    fn diffusion_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let k = self.problem.nparams();
        let d = self.dim;
        let bsz = z.len() / d;
        let mut dth = vec![0.0; k];
        for i in 0..d {
            let th = self.th(theta, i);
            for b in 0..bsz {
                let zi = z[b * d + i];
                let ai = a[b * d + i];
                out_z[b * d + i] += ai * self.problem.diffusion_dx(t, zi, th);
                self.problem.diffusion_dtheta(t, zi, th, &mut dth);
                let row = &mut out_theta[b * d * k + i * k..b * d * k + (i + 1) * k];
                for j in 0..k {
                    row[j] += ai * dth[j];
                }
            }
        }
    }

    fn ito_correction_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        self.ito_correction_vjp_fast_signed(t, z, theta, a, 1.0, out_z, out_theta);
    }

    fn drift_vjp_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        _scratch: &mut [f64],
    ) {
        self.drift_vjp_batch_fast(t, z, theta, a, out_z, out_theta);
        if self.problem.calculus() == Calculus::Ito {
            // aᵀ∂(b−c)/∂· : the correction accumulates with flipped sign,
            // folded into the sweep instead of staging −a per row.
            self.ito_correction_vjp_fast_signed(t, z, theta, a, -1.0, out_z, out_theta);
        }
    }
}

/// Every §7.1 scalar problem's closed-form solution depends on the path
/// only through `W_{t1}`, so the exact-solution oracle for a replicated
/// problem needs exactly one Brownian query (the endpoint) — this is the
/// GBM-style oracle of the [`crate::convergence`] subsystem.
///
/// The closed forms treat `span.0` as the problem's time origin (elapsed
/// time `t1 − t0` is what enters `analytic_solution`), which is exact for
/// the time-homogeneous Examples 1–2 and for Example 3 when `span.0 = 0`
/// (its coefficients reference absolute time). A nonzero `span.0` on a
/// time-*inhomogeneous* problem would silently make the oracle describe a
/// different process than the solver, so it is rejected at run time (see
/// [`ReplicatedSde::check_time_origin`]).
impl<P: ScalarSde> ReplicatedSde<P> {
    /// Panic unless the oracle's time-origin convention is valid for
    /// `span`: either `span.0 = 0`, or the coefficients don't depend on
    /// absolute time (probed at the initial state — catches Example 3's
    /// `1/√(1+t)` factors immediately).
    fn check_time_origin(&self, span: (f64, f64), z0: &[f64], theta: &[f64]) {
        let t0 = span.0;
        if t0 == 0.0 {
            return;
        }
        for i in 0..self.dim {
            let th = self.th(theta, i);
            let p = &self.problem;
            let homogeneous = p.drift(t0, z0[i], th) == p.drift(0.0, z0[i], th)
                && p.diffusion(t0, z0[i], th) == p.diffusion(0.0, z0[i], th);
            assert!(
                homogeneous,
                "ExactSolution for ReplicatedSde<{}>: closed form assumes the problem starts \
                 at time 0, but span starts at {t0} and the coefficients depend on absolute \
                 time — shift the problem to a (0, T) horizon",
                self.problem.name()
            );
        }
    }
}

impl<P: ScalarSde> ExactSolution for ReplicatedSde<P> {
    fn exact_state(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        out: &mut [f64],
    ) {
        self.check_time_origin(span, z0, theta);
        let (t0, t1) = span;
        let d = self.dim;
        let mut w = vec![0.0; d];
        let mut w0 = vec![0.0; d];
        bm.sample_into(t0, &mut w0);
        bm.sample_into(t1, &mut w);
        for (wi, w0i) in w.iter_mut().zip(&w0) {
            *wi -= w0i;
        }
        self.analytic_solution(t1 - t0, z0, theta, &w, out);
    }

    fn exact_sum_gradients(
        &self,
        span: (f64, f64),
        z0: &[f64],
        theta: &[f64],
        bm: &mut dyn BrownianMotion,
        grad_z0: &mut [f64],
        grad_theta: &mut [f64],
    ) {
        self.check_time_origin(span, z0, theta);
        let (t0, t1) = span;
        let d = self.dim;
        let mut w = vec![0.0; d];
        let mut w0 = vec![0.0; d];
        bm.sample_into(t0, &mut w0);
        bm.sample_into(t1, &mut w);
        for (wi, w0i) in w.iter_mut().zip(&w0) {
            *wi -= w0i;
        }
        self.analytic_loss_gradients(t1 - t0, z0, theta, &w, grad_z0, grad_theta);
    }
}

/// Sample the §7.1 experiment setup: per-dimension parameters drawn from
/// `sigmoid(N(0,1))` and initial values from `N(μ0, s0²)` (positive-shifted
/// so Example 1/2 gradients are well-defined).
pub fn sample_experiment_setup(
    key: crate::prng::PrngKey,
    dim: usize,
    nparams: usize,
) -> (Vec<f64>, Vec<f64>) {
    let (kp, kx) = key.split();
    let mut theta = vec![0.0; dim * nparams];
    kp.fill_normal(0, &mut theta);
    for v in theta.iter_mut() {
        *v = 1.0 / (1.0 + (-*v).exp()); // sigmoid -> (0, 1)
    }
    let mut x0 = vec![0.0; dim];
    kx.fill_normal(0, &mut x0);
    for v in x0.iter_mut() {
        *v = 0.6 + 0.2 * *v; // N(0.6, 0.04): bounded away from 0
    }
    (theta, x0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central-difference check of every analytic partial on a ScalarSde.
    fn check_partials<P: ScalarSde>(p: &P, t: f64, x: f64, th: &[f64]) {
        let k = p.nparams();
        let eps = 1e-6;
        let tol = 1e-5;

        // drift_dx
        let fd = (p.drift(t, x + eps, th) - p.drift(t, x - eps, th)) / (2.0 * eps);
        assert!(
            (fd - p.drift_dx(t, x, th)).abs() < tol,
            "{}: drift_dx analytic {} vs fd {}",
            p.name(),
            p.drift_dx(t, x, th),
            fd
        );
        // diffusion_dx
        let fd = (p.diffusion(t, x + eps, th) - p.diffusion(t, x - eps, th)) / (2.0 * eps);
        assert!((fd - p.diffusion_dx(t, x, th)).abs() < tol, "{}: diffusion_dx", p.name());
        // diffusion_dxx
        let fd =
            (p.diffusion_dx(t, x + eps, th) - p.diffusion_dx(t, x - eps, th)) / (2.0 * eps);
        assert!((fd - p.diffusion_dxx(t, x, th)).abs() < tol, "{}: diffusion_dxx", p.name());

        let mut thp = th.to_vec();
        let mut grad = vec![0.0; k];
        // drift_dtheta
        p.drift_dtheta(t, x, th, &mut grad);
        for j in 0..k {
            thp.copy_from_slice(th);
            thp[j] += eps;
            let hi = p.drift(t, x, &thp);
            thp[j] -= 2.0 * eps;
            let lo = p.drift(t, x, &thp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - grad[j]).abs() < tol, "{}: drift_dtheta[{j}]", p.name());
        }
        // diffusion_dtheta
        p.diffusion_dtheta(t, x, th, &mut grad);
        for j in 0..k {
            thp.copy_from_slice(th);
            thp[j] += eps;
            let hi = p.diffusion(t, x, &thp);
            thp[j] -= 2.0 * eps;
            let lo = p.diffusion(t, x, &thp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - grad[j]).abs() < tol, "{}: diffusion_dtheta[{j}]", p.name());
        }
        // diffusion_dx_dtheta
        p.diffusion_dx_dtheta(t, x, th, &mut grad);
        for j in 0..k {
            thp.copy_from_slice(th);
            thp[j] += eps;
            let hi = p.diffusion_dx(t, x, &thp);
            thp[j] -= 2.0 * eps;
            let lo = p.diffusion_dx(t, x, &thp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - grad[j]).abs() < tol, "{}: diffusion_dx_dtheta[{j}]", p.name());
        }
    }

    /// The analytic pathwise gradients must match finite differences of the
    /// analytic solution (holding W fixed).
    fn check_analytic_grads<P: ScalarSde>(p: &P, t: f64, x0: f64, th: &[f64], w: f64) {
        let k = p.nparams();
        let mut grads = vec![0.0; 1 + k];
        p.analytic_gradients(t, x0, th, w, &mut grads);
        let eps = 1e-6;
        let fd_x0 = (p.analytic_solution(t, x0 + eps, th, w)
            - p.analytic_solution(t, x0 - eps, th, w))
            / (2.0 * eps);
        assert!((fd_x0 - grads[0]).abs() < 1e-5, "{}: analytic grad x0", p.name());
        let mut thp = th.to_vec();
        for j in 0..k {
            thp.copy_from_slice(th);
            thp[j] += eps;
            let hi = p.analytic_solution(t, x0, &thp, w);
            thp[j] -= 2.0 * eps;
            let lo = p.analytic_solution(t, x0, &thp, w);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - grads[1 + j]).abs() < 1e-5, "{}: analytic grad θ[{j}]", p.name());
        }
    }

    #[test]
    fn example1_partials_and_gradients() {
        let p = Example1;
        check_partials(&p, 0.3, 0.8, &[0.6, 0.4]);
        check_analytic_grads(&p, 1.0, 0.7, &[0.6, 0.4], 0.35);
    }

    #[test]
    fn example2_partials_and_gradients() {
        let p = Example2;
        check_partials(&p, 0.1, 0.5, &[0.7]);
        check_analytic_grads(&p, 1.0, 0.5, &[0.7], -0.2);
    }

    #[test]
    fn example3_partials_and_gradients() {
        let p = Example3;
        check_partials(&p, 0.4, 1.1, &[0.5, 0.9]);
        check_analytic_grads(&p, 1.0, 1.1, &[0.5, 0.9], 0.15);
    }

    #[test]
    fn example2_stratonovich_drift_vanishes() {
        // b_strat = b − ½σσ' must be ~0 for Example 2 (see module docs).
        let sde = ReplicatedSde::new(Example2, 3);
        let z = [0.3, 0.9, -0.4];
        let theta = [0.5, 0.7, 0.9];
        let mut out = [0.0; 3];
        let mut scratch = [0.0; 6];
        sde.drift_stratonovich(0.0, &z, &theta, &mut out, &mut scratch);
        for v in out {
            assert!(v.abs() < 1e-12, "strat drift should vanish, got {v}");
        }
    }

    #[test]
    fn replicated_layout_and_independence() {
        let sde = ReplicatedSde::new(Example1, 4);
        assert_eq!(sde.state_dim(), 4);
        assert_eq!(sde.param_dim(), 8);
        let z = [1.0, 2.0, 3.0, 4.0];
        let theta = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        let mut out = [0.0; 4];
        sde.drift(0.0, &z, &theta, &mut out);
        // dim i drift = α_i z_i with α_i = theta[2i]
        assert_eq!(out, [0.1 * 1.0, 0.3 * 2.0, 0.5 * 3.0, 0.7 * 4.0]);
    }

    #[test]
    fn replicated_vjps_match_finite_difference() {
        let sde = ReplicatedSde::new(Example2, 3);
        let z = [0.3, 0.9, -0.4];
        let theta = [0.5, 0.7, 0.9];
        let a = [1.0, -2.0, 0.5];
        let t = 0.2;
        let eps = 1e-6;

        let mut vz = vec![0.0; 3];
        let mut vth = vec![0.0; 3];
        sde.drift_vjp(t, &z, &theta, &a, &mut vz, &mut vth);

        let mut buf_hi = [0.0; 3];
        let mut buf_lo = [0.0; 3];
        for i in 0..3 {
            let mut zp = z;
            zp[i] += eps;
            sde.drift(t, &zp, &theta, &mut buf_hi);
            zp[i] -= 2.0 * eps;
            sde.drift(t, &zp, &theta, &mut buf_lo);
            let fd: f64 = (0..3).map(|r| a[r] * (buf_hi[r] - buf_lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vz[i]).abs() < 1e-5, "drift_vjp z[{i}]: {fd} vs {}", vz[i]);
        }
        for j in 0..3 {
            let mut tp = theta;
            tp[j] += eps;
            sde.drift(t, &z, &tp, &mut buf_hi);
            tp[j] -= 2.0 * eps;
            sde.drift(t, &z, &tp, &mut buf_lo);
            let fd: f64 = (0..3).map(|r| a[r] * (buf_hi[r] - buf_lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vth[j]).abs() < 1e-5, "drift_vjp θ[{j}]: {fd} vs {}", vth[j]);
        }
    }

    #[test]
    fn ito_correction_vjp_matches_finite_difference() {
        let sde = ReplicatedSde::new(Example2, 2);
        let z = [0.4, -0.7];
        let theta = [0.6, 0.8];
        let a = [1.5, -0.5];
        let t = 0.0;
        let eps = 1e-6;

        let mut vz = vec![0.0; 2];
        let mut vth = vec![0.0; 2];
        sde.ito_correction_vjp(t, &z, &theta, &a, &mut vz, &mut vth);

        let corr = |z: &[f64; 2], th: &[f64; 2]| -> [f64; 2] {
            let mut sig = [0.0; 2];
            let mut dsig = [0.0; 2];
            sde.diffusion(t, z, th, &mut sig);
            sde.diffusion_dz_diag(t, z, th, &mut dsig);
            [0.5 * sig[0] * dsig[0], 0.5 * sig[1] * dsig[1]]
        };
        for i in 0..2 {
            let mut zp = z;
            zp[i] += eps;
            let hi = corr(&zp, &theta);
            zp[i] -= 2.0 * eps;
            let lo = corr(&zp, &theta);
            let fd: f64 = (0..2).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vz[i]).abs() < 1e-5, "corr vjp z[{i}]: {fd} vs {}", vz[i]);
        }
        for j in 0..2 {
            let mut tp = theta;
            tp[j] += eps;
            let hi = corr(&z, &tp);
            tp[j] -= 2.0 * eps;
            let lo = corr(&z, &tp);
            let fd: f64 = (0..2).map(|r| a[r] * (hi[r] - lo[r]) / (2.0 * eps)).sum();
            assert!((fd - vth[j]).abs() < 1e-5, "corr vjp θ[{j}]: {fd} vs {}", vth[j]);
        }
    }

    #[test]
    fn setup_sampler_ranges() {
        let (theta, x0) = sample_experiment_setup(crate::prng::PrngKey::from_seed(1), 10, 2);
        assert_eq!(theta.len(), 20);
        assert_eq!(x0.len(), 10);
        for &v in &theta {
            assert!(v > 0.0 && v < 1.0, "sigmoid out of range: {v}");
        }
    }
}
