//! Batched structure-of-arrays (SoA) SDE evaluation.
//!
//! The scalar [`Sde`]/[`SdeVjp`] traits work on one state vector of length
//! `d` at a time, which makes every Monte Carlo workload pay B virtual
//! calls (and B passes over the parameter vector) per solver stage. The
//! batch traits below evaluate **B sample paths at once** over contiguous
//! row-major `[B×d]` buffers: path `b` occupies `buf[b*d .. (b+1)*d]`.
//!
//! Two-level design:
//!
//! * **Loop-based defaults.** Every method has a default body that chunks
//!   the `[B×d]` buffers into rows and calls the scalar trait method per
//!   row. Because the per-row arithmetic is *exactly* the scalar
//!   engine's, results are bit-identical to a per-path loop — the batch
//!   engine can therefore replace the scalar one without changing a
//!   single float (pinned by `tests/batch_engine.rs`).
//! * **Hand-batched overrides.** Systems with structure override the
//!   defaults: [`super::ReplicatedSde`] hoists the per-dimension
//!   parameter slicing out of the path loop, and the `nn`-backed
//!   [`crate::latent::PosteriorSde`] turns B matrix–vector MLP passes
//!   into one blocked `[B×in]·[in×out]` pass that keeps each weight row
//!   hot across all B paths. Overrides must preserve the per-path float
//!   sequence (same additions in the same order) so the bit-identity
//!   guarantee survives.
//!
//! All paths share one parameter vector θ and one evaluation time `t`
//! (the batch engine is for replicates of a single problem over
//! independent Brownian paths — see [`crate::api::solve_batch`]); only
//! state, noise, and adjoint rows vary per path.

use super::traits::{Sde, SdeVjp};

/// Which kernel family executes a batched computation.
///
/// * [`KernelTier::Exact`] (the default) is the oracle: every per-path
///   float follows the scalar engine's evaluation order exactly, so a
///   batch of B paths equals B scalar solves bit for bit. No
///   reassociation, no fusion that changes rounding.
/// * [`KernelTier::Fast`] routes batched execution through blocked,
///   dimension-major sweep kernels shaped for autovectorization: fused
///   drift+diffusion evaluation, matrix-matrix MLP/GRU passes free to
///   reassociate accumulations, and flat elementwise kernels for
///   structured systems. Results are validated against the exact tier to
///   a stated **relative tolerance** (`tests/fast_tier.rs`), not bit
///   identity.
///
/// The tier is selected per call site ([`crate::api::SolveOptions`], the
/// trainer/serve configs, and the bench CLI); the exact tier remains the
/// default everywhere.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// Bit-identical to per-path scalar execution (default; the oracle).
    #[default]
    Exact,
    /// Autovectorization-friendly kernels, validated to tolerance.
    Fast,
}

impl KernelTier {
    /// Stable lowercase name (CLI/bench row vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            KernelTier::Exact => "exact",
            KernelTier::Fast => "fast",
        }
    }

    /// Parse a CLI spelling (`"exact"` / `"fast"`).
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s {
            "exact" => Some(KernelTier::Exact),
            "fast" => Some(KernelTier::Fast),
            _ => None,
        }
    }
}

/// Batched evaluation of an [`Sde`] over `[B×d]` state buffers.
///
/// Implement with `impl BatchSde for MySde {}` to get the loop-based
/// defaults; override individual methods for hand-batched kernels. The
/// batch size is implied by the buffer lengths (`z.len() / state_dim`).
pub trait BatchSde: Sde {
    /// Drift of every path: `out[b] = b(z[b], t, θ)` for each row.
    fn drift_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.drift(t, zr, theta, or);
        }
    }

    /// Diagonal diffusion of every path.
    fn diffusion_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.diffusion(t, zr, theta, or);
        }
    }

    /// `∂σ_i/∂z_i` of every path (Milstein schemes, Itô↔Stratonovich
    /// conversion).
    fn diffusion_dz_diag_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.diffusion_dz_diag(t, zr, theta, or);
        }
    }

    /// Stratonovich drift of every path. `scratch` must hold at least
    /// `2·d` floats (row-level σ/σ′ staging, reused across rows).
    fn drift_stratonovich_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        out: &mut [f64],
        scratch: &mut [f64],
    ) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.drift_stratonovich(t, zr, theta, or, scratch);
        }
    }

    // ── Fast-tier kernels ──────────────────────────────────────────────
    //
    // Every `*_fast` method defaults to its exact counterpart, so plain
    // `impl BatchSde for T {}` systems behave identically on both tiers.
    // Systems with structure override these with fused / flat /
    // reassociation-free-of-pinning sweeps; overrides may change the
    // float evaluation order but must stay within the relative tolerance
    // pinned by `tests/fast_tier.rs`.

    /// Fast-tier drift. Default: the exact kernel.
    fn drift_batch_fast(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        self.drift_batch(t, z, theta, out);
    }

    /// Fast-tier diagonal diffusion. Default: the exact kernel.
    fn diffusion_batch_fast(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        self.diffusion_batch(t, z, theta, out);
    }

    /// Fast-tier `∂σ_i/∂z_i`. Default: the exact kernel.
    fn diffusion_dz_diag_batch_fast(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        self.diffusion_dz_diag_batch(t, z, theta, out);
    }

    /// Fast-tier Stratonovich drift (same `scratch` contract as the
    /// exact kernel). Default: the exact kernel.
    fn drift_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        out: &mut [f64],
        scratch: &mut [f64],
    ) {
        self.drift_stratonovich_batch(t, z, theta, out, scratch);
    }

    /// Fused fast-tier drift **and** diffusion in one sweep over the
    /// state buffer — the hot call of every explicit scheme's first
    /// stage. Default: two separate fast kernels; structured systems
    /// override with a single pass that keeps each `z` cell hot for both
    /// coefficients.
    fn drift_diffusion_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        f_out: &mut [f64],
        g_out: &mut [f64],
    ) {
        self.drift_batch_fast(t, z, theta, f_out);
        self.diffusion_batch_fast(t, z, theta, g_out);
    }
}

/// Batched vector-Jacobian products for the batched stochastic adjoint.
///
/// Adjoint rows `a` are `[B×d]`; the parameter-side outputs are **per
/// path** (`[B×p]`, row `b` accumulating path `b`'s `aᵀ∂·/∂θ`) so each
/// path's gradient stays independent, exactly as B scalar adjoint solves
/// would produce. All VJPs accumulate into their outputs, mirroring the
/// scalar [`SdeVjp`] convention.
pub trait BatchSdeVjp: BatchSde + SdeVjp {
    /// Accumulate `a[b]ᵀ∂b/∂z → out_z[b]` and `a[b]ᵀ∂b/∂θ → out_theta[b]`
    /// for every path.
    fn drift_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.drift_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate `a[b]ᵀ∂σ/∂z` and `a[b]ᵀ∂σ/∂θ` for every path.
    fn diffusion_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.diffusion_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate the Itô→Stratonovich correction VJP for every path.
    /// Panics (like the scalar default) when the system does not provide
    /// [`SdeVjp::ito_correction_vjp`]; the problem API validates this
    /// before integrating.
    fn ito_correction_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.ito_correction_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate the Stratonovich-form drift VJP for every path.
    /// `scratch` must hold at least `d` floats (row-level sign-flip
    /// staging, reused across rows).
    #[allow(clippy::too_many_arguments)]
    fn drift_vjp_stratonovich_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        scratch: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.drift_vjp_stratonovich(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
                scratch,
            );
        }
    }

    // ── Fast-tier VJP kernels ──────────────────────────────────────────
    //
    // Same contract and default-to-exact convention as the forward-side
    // fast kernels on [`BatchSde`]: per-path `[B×p]` accumulation,
    // overrides free to hoist scratch and sweep dimension-major.

    /// Fast-tier batched drift VJP. Default: the exact kernel.
    fn drift_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        self.drift_vjp_batch(t, z, theta, a, out_z, out_theta);
    }

    /// Fast-tier batched diffusion VJP. Default: the exact kernel.
    fn diffusion_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        self.diffusion_vjp_batch(t, z, theta, a, out_z, out_theta);
    }

    /// Fast-tier batched Itô→Stratonovich correction VJP. Default: the
    /// exact kernel (panics when the system provides no correction VJP).
    fn ito_correction_vjp_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        self.ito_correction_vjp_batch(t, z, theta, a, out_z, out_theta);
    }

    /// Fast-tier batched Stratonovich drift VJP (same `scratch` contract
    /// as the exact kernel). Default: the exact kernel.
    #[allow(clippy::too_many_arguments)]
    fn drift_vjp_stratonovich_batch_fast(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        scratch: &mut [f64],
    ) {
        self.drift_vjp_stratonovich_batch(t, z, theta, a, out_z, out_theta, scratch);
    }
}

#[cfg(test)]
mod tests {
    use crate::prng::PrngKey;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};
    use crate::sde::{BatchSde, BatchSdeVjp, ReplicatedSde, Sde, SdeVjp};

    /// Batched evaluation must equal a per-path scalar loop exactly —
    /// including for the hand-batched ReplicatedSde overrides.
    #[test]
    fn batched_evaluations_match_scalar_rows_exactly() {
        let dim = 3;
        let batch = 5;
        let sde = ReplicatedSde::new(Example2, dim);
        let key = PrngKey::from_seed(17);
        let (theta, _) = sample_experiment_setup(key, dim, 1);
        let mut z = vec![0.0; batch * dim];
        key.fill_normal(7, &mut z);
        let mut a = vec![0.0; batch * dim];
        key.fill_normal(99, &mut a);
        let t = 0.3;
        let p = sde.param_dim();

        let mut out_b = vec![0.0; batch * dim];
        sde.drift_batch(t, &z, &theta, &mut out_b);
        let mut sig_b = vec![0.0; batch * dim];
        sde.diffusion_batch(t, &z, &theta, &mut sig_b);
        let mut dsig_b = vec![0.0; batch * dim];
        sde.diffusion_dz_diag_batch(t, &z, &theta, &mut dsig_b);
        let mut strat_b = vec![0.0; batch * dim];
        let mut scratch = vec![0.0; 2 * dim];
        sde.drift_stratonovich_batch(t, &z, &theta, &mut strat_b, &mut scratch);
        let mut vz_b = vec![0.0; batch * dim];
        let mut vth_b = vec![0.0; batch * p];
        sde.drift_vjp_batch(t, &z, &theta, &a, &mut vz_b, &mut vth_b);
        let mut gz_b = vec![0.0; batch * dim];
        let mut gth_b = vec![0.0; batch * p];
        sde.diffusion_vjp_batch(t, &z, &theta, &a, &mut gz_b, &mut gth_b);

        for b in 0..batch {
            let zr = &z[b * dim..(b + 1) * dim];
            let ar = &a[b * dim..(b + 1) * dim];
            let mut row = vec![0.0; dim];
            sde.drift(t, zr, &theta, &mut row);
            assert_eq!(&out_b[b * dim..(b + 1) * dim], &row[..], "drift row {b}");
            sde.diffusion(t, zr, &theta, &mut row);
            assert_eq!(&sig_b[b * dim..(b + 1) * dim], &row[..], "diffusion row {b}");
            sde.diffusion_dz_diag(t, zr, &theta, &mut row);
            assert_eq!(&dsig_b[b * dim..(b + 1) * dim], &row[..], "σ′ row {b}");
            let mut sc = vec![0.0; 2 * dim];
            sde.drift_stratonovich(t, zr, &theta, &mut row, &mut sc);
            assert_eq!(&strat_b[b * dim..(b + 1) * dim], &row[..], "strat row {b}");
            let mut vz = vec![0.0; dim];
            let mut vth = vec![0.0; p];
            sde.drift_vjp(t, zr, &theta, ar, &mut vz, &mut vth);
            assert_eq!(&vz_b[b * dim..(b + 1) * dim], &vz[..], "drift vjp z row {b}");
            assert_eq!(&vth_b[b * p..(b + 1) * p], &vth[..], "drift vjp θ row {b}");
            let mut gz = vec![0.0; dim];
            let mut gth = vec![0.0; p];
            sde.diffusion_vjp(t, zr, &theta, ar, &mut gz, &mut gth);
            assert_eq!(&gz_b[b * dim..(b + 1) * dim], &gz[..], "diff vjp z row {b}");
            assert_eq!(&gth_b[b * p..(b + 1) * p], &gth[..], "diff vjp θ row {b}");
        }
    }

    /// Parameter-side VJP rows are independent per path (no cross-path
    /// accumulation).
    #[test]
    fn theta_rows_are_per_path() {
        let dim = 2;
        let sde = ReplicatedSde::new(Example1, dim);
        let theta = [0.4, 0.6, 0.8, 0.2];
        let z = [1.0, 2.0, 3.0, 4.0]; // two paths
        let a = [1.0, 0.0, 0.0, 0.0]; // only path 0, dim 0 has adjoint mass
        let mut vz = vec![0.0; 4];
        let mut vth = vec![0.0; 2 * 4];
        sde.drift_vjp_batch(0.0, &z, &theta, &a, &mut vz, &mut vth);
        assert!(vth[..4].iter().any(|v| *v != 0.0), "path 0 gets gradient");
        assert!(vth[4..].iter().all(|v| *v == 0.0), "path 1 stays zero");
    }

    /// The tier selector's CLI vocabulary round-trips, and Exact is the
    /// default.
    #[test]
    fn kernel_tier_vocabulary() {
        use crate::sde::KernelTier;
        assert_eq!(KernelTier::default(), KernelTier::Exact);
        for tier in [KernelTier::Exact, KernelTier::Fast] {
            assert_eq!(KernelTier::parse(tier.name()), Some(tier));
        }
        assert_eq!(KernelTier::parse("turbo"), None);
    }

    /// The fused fast-tier kernel agrees with the separate exact kernels
    /// for the hand-batched problems (their per-cell expressions are the
    /// same scalar calls; only the sweep is fused).
    #[test]
    fn fused_fast_kernel_matches_exact() {
        let dim = 3;
        let batch = 5;
        let sde = ReplicatedSde::new(Example2, dim);
        let key = PrngKey::from_seed(23);
        let (theta, _) = sample_experiment_setup(key, dim, 1);
        let mut z = vec![0.0; batch * dim];
        key.fill_normal(11, &mut z);
        let t = 0.4;

        let mut f_exact = vec![0.0; batch * dim];
        let mut g_exact = vec![0.0; batch * dim];
        sde.drift_batch(t, &z, &theta, &mut f_exact);
        sde.diffusion_batch(t, &z, &theta, &mut g_exact);

        let mut f_fast = vec![0.0; batch * dim];
        let mut g_fast = vec![0.0; batch * dim];
        sde.drift_diffusion_batch_fast(t, &z, &theta, &mut f_fast, &mut g_fast);

        for i in 0..batch * dim {
            assert!((f_fast[i] - f_exact[i]).abs() <= 1e-12 * f_exact[i].abs().max(1.0));
            assert!((g_fast[i] - g_exact[i]).abs() <= 1e-12 * g_exact[i].abs().max(1.0));
        }
    }
}
