//! Batched structure-of-arrays (SoA) SDE evaluation.
//!
//! The scalar [`Sde`]/[`SdeVjp`] traits work on one state vector of length
//! `d` at a time, which makes every Monte Carlo workload pay B virtual
//! calls (and B passes over the parameter vector) per solver stage. The
//! batch traits below evaluate **B sample paths at once** over contiguous
//! row-major `[B×d]` buffers: path `b` occupies `buf[b*d .. (b+1)*d]`.
//!
//! Two-level design:
//!
//! * **Loop-based defaults.** Every method has a default body that chunks
//!   the `[B×d]` buffers into rows and calls the scalar trait method per
//!   row. Because the per-row arithmetic is *exactly* the scalar
//!   engine's, results are bit-identical to a per-path loop — the batch
//!   engine can therefore replace the scalar one without changing a
//!   single float (pinned by `tests/batch_engine.rs`).
//! * **Hand-batched overrides.** Systems with structure override the
//!   defaults: [`super::ReplicatedSde`] hoists the per-dimension
//!   parameter slicing out of the path loop, and the `nn`-backed
//!   [`crate::latent::PosteriorSde`] turns B matrix–vector MLP passes
//!   into one blocked `[B×in]·[in×out]` pass that keeps each weight row
//!   hot across all B paths. Overrides must preserve the per-path float
//!   sequence (same additions in the same order) so the bit-identity
//!   guarantee survives.
//!
//! All paths share one parameter vector θ and one evaluation time `t`
//! (the batch engine is for replicates of a single problem over
//! independent Brownian paths — see [`crate::api::solve_batch`]); only
//! state, noise, and adjoint rows vary per path.

use super::traits::{Sde, SdeVjp};

/// Batched evaluation of an [`Sde`] over `[B×d]` state buffers.
///
/// Implement with `impl BatchSde for MySde {}` to get the loop-based
/// defaults; override individual methods for hand-batched kernels. The
/// batch size is implied by the buffer lengths (`z.len() / state_dim`).
pub trait BatchSde: Sde {
    /// Drift of every path: `out[b] = b(z[b], t, θ)` for each row.
    fn drift_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.drift(t, zr, theta, or);
        }
    }

    /// Diagonal diffusion of every path.
    fn diffusion_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.diffusion(t, zr, theta, or);
        }
    }

    /// `∂σ_i/∂z_i` of every path (Milstein schemes, Itô↔Stratonovich
    /// conversion).
    fn diffusion_dz_diag_batch(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.diffusion_dz_diag(t, zr, theta, or);
        }
    }

    /// Stratonovich drift of every path. `scratch` must hold at least
    /// `2·d` floats (row-level σ/σ′ staging, reused across rows).
    fn drift_stratonovich_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        out: &mut [f64],
        scratch: &mut [f64],
    ) {
        let d = self.state_dim();
        debug_assert_eq!(z.len(), out.len());
        for (zr, or) in z.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.drift_stratonovich(t, zr, theta, or, scratch);
        }
    }
}

/// Batched vector-Jacobian products for the batched stochastic adjoint.
///
/// Adjoint rows `a` are `[B×d]`; the parameter-side outputs are **per
/// path** (`[B×p]`, row `b` accumulating path `b`'s `aᵀ∂·/∂θ`) so each
/// path's gradient stays independent, exactly as B scalar adjoint solves
/// would produce. All VJPs accumulate into their outputs, mirroring the
/// scalar [`SdeVjp`] convention.
pub trait BatchSdeVjp: BatchSde + SdeVjp {
    /// Accumulate `a[b]ᵀ∂b/∂z → out_z[b]` and `a[b]ᵀ∂b/∂θ → out_theta[b]`
    /// for every path.
    fn drift_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.drift_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate `a[b]ᵀ∂σ/∂z` and `a[b]ᵀ∂σ/∂θ` for every path.
    fn diffusion_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.diffusion_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate the Itô→Stratonovich correction VJP for every path.
    /// Panics (like the scalar default) when the system does not provide
    /// [`SdeVjp::ito_correction_vjp`]; the problem API validates this
    /// before integrating.
    fn ito_correction_vjp_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.ito_correction_vjp(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
            );
        }
    }

    /// Accumulate the Stratonovich-form drift VJP for every path.
    /// `scratch` must hold at least `d` floats (row-level sign-flip
    /// staging, reused across rows).
    #[allow(clippy::too_many_arguments)]
    fn drift_vjp_stratonovich_batch(
        &self,
        t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
        scratch: &mut [f64],
    ) {
        let d = self.state_dim();
        let p = self.param_dim();
        let bsz = z.len() / d;
        for b in 0..bsz {
            self.drift_vjp_stratonovich(
                t,
                &z[b * d..(b + 1) * d],
                theta,
                &a[b * d..(b + 1) * d],
                &mut out_z[b * d..(b + 1) * d],
                &mut out_theta[b * p..(b + 1) * p],
                scratch,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::prng::PrngKey;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};
    use crate::sde::{BatchSde, BatchSdeVjp, ReplicatedSde, Sde, SdeVjp};

    /// Batched evaluation must equal a per-path scalar loop exactly —
    /// including for the hand-batched ReplicatedSde overrides.
    #[test]
    fn batched_evaluations_match_scalar_rows_exactly() {
        let dim = 3;
        let batch = 5;
        let sde = ReplicatedSde::new(Example2, dim);
        let key = PrngKey::from_seed(17);
        let (theta, _) = sample_experiment_setup(key, dim, 1);
        let mut z = vec![0.0; batch * dim];
        key.fill_normal(7, &mut z);
        let mut a = vec![0.0; batch * dim];
        key.fill_normal(99, &mut a);
        let t = 0.3;
        let p = sde.param_dim();

        let mut out_b = vec![0.0; batch * dim];
        sde.drift_batch(t, &z, &theta, &mut out_b);
        let mut sig_b = vec![0.0; batch * dim];
        sde.diffusion_batch(t, &z, &theta, &mut sig_b);
        let mut dsig_b = vec![0.0; batch * dim];
        sde.diffusion_dz_diag_batch(t, &z, &theta, &mut dsig_b);
        let mut strat_b = vec![0.0; batch * dim];
        let mut scratch = vec![0.0; 2 * dim];
        sde.drift_stratonovich_batch(t, &z, &theta, &mut strat_b, &mut scratch);
        let mut vz_b = vec![0.0; batch * dim];
        let mut vth_b = vec![0.0; batch * p];
        sde.drift_vjp_batch(t, &z, &theta, &a, &mut vz_b, &mut vth_b);
        let mut gz_b = vec![0.0; batch * dim];
        let mut gth_b = vec![0.0; batch * p];
        sde.diffusion_vjp_batch(t, &z, &theta, &a, &mut gz_b, &mut gth_b);

        for b in 0..batch {
            let zr = &z[b * dim..(b + 1) * dim];
            let ar = &a[b * dim..(b + 1) * dim];
            let mut row = vec![0.0; dim];
            sde.drift(t, zr, &theta, &mut row);
            assert_eq!(&out_b[b * dim..(b + 1) * dim], &row[..], "drift row {b}");
            sde.diffusion(t, zr, &theta, &mut row);
            assert_eq!(&sig_b[b * dim..(b + 1) * dim], &row[..], "diffusion row {b}");
            sde.diffusion_dz_diag(t, zr, &theta, &mut row);
            assert_eq!(&dsig_b[b * dim..(b + 1) * dim], &row[..], "σ′ row {b}");
            let mut sc = vec![0.0; 2 * dim];
            sde.drift_stratonovich(t, zr, &theta, &mut row, &mut sc);
            assert_eq!(&strat_b[b * dim..(b + 1) * dim], &row[..], "strat row {b}");
            let mut vz = vec![0.0; dim];
            let mut vth = vec![0.0; p];
            sde.drift_vjp(t, zr, &theta, ar, &mut vz, &mut vth);
            assert_eq!(&vz_b[b * dim..(b + 1) * dim], &vz[..], "drift vjp z row {b}");
            assert_eq!(&vth_b[b * p..(b + 1) * p], &vth[..], "drift vjp θ row {b}");
            let mut gz = vec![0.0; dim];
            let mut gth = vec![0.0; p];
            sde.diffusion_vjp(t, zr, &theta, ar, &mut gz, &mut gth);
            assert_eq!(&gz_b[b * dim..(b + 1) * dim], &gz[..], "diff vjp z row {b}");
            assert_eq!(&gth_b[b * p..(b + 1) * p], &gth[..], "diff vjp θ row {b}");
        }
    }

    /// Parameter-side VJP rows are independent per path (no cross-path
    /// accumulation).
    #[test]
    fn theta_rows_are_per_path() {
        let dim = 2;
        let sde = ReplicatedSde::new(Example1, dim);
        let theta = [0.4, 0.6, 0.8, 0.2];
        let z = [1.0, 2.0, 3.0, 4.0]; // two paths
        let a = [1.0, 0.0, 0.0, 0.0]; // only path 0, dim 0 has adjoint mass
        let mut vz = vec![0.0; 4];
        let mut vth = vec![0.0; 2 * 4];
        sde.drift_vjp_batch(0.0, &z, &theta, &a, &mut vz, &mut vth);
        assert!(vth[..4].iter().any(|v| *v != 0.0), "path 0 gets gradient");
        assert!(vth[4..].iter().all(|v| *v == 0.0), "path 1 stays zero");
    }
}
