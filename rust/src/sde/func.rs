//! Flat-state system interface consumed by the numerical integrators.
//!
//! Integrators (see [`crate::solvers`]) know nothing about parameters,
//! adjoints, or augmentation — they step a flat state vector `y` through
//! `dy = f(t, y) dt + g(t, y) ∘ dW` (or the Itô reading, per scheme) with
//! *diagonal* `g`. Adapters implement [`SdeFunc`]:
//!
//! * [`ForwardFunc`] — a plain forward solve of an [`Sde`] at fixed `θ`;
//! * `adjoint::AugmentedBackward` — the augmented (z, a_z, a_θ) system;
//! * `latent::ElboFunc` — latent-SDE state augmented with the running KL.
//!
//! Methods take `&mut self` so adapters can use internal scratch buffers
//! and count function evaluations (the paper reports NFE in Fig 5b).

use super::traits::{Calculus, Sde};

/// A flat-state diagonal-noise SDE as seen by integrators.
pub trait SdeFunc {
    /// Flat state dimension.
    fn dim(&self) -> usize;

    /// Calculus in which `drift`/`diffusion` are expressed.
    fn calculus(&self) -> Calculus;

    /// Drift into `out`.
    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]);

    /// Diagonal diffusion into `out`.
    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]);

    /// Whether [`SdeFunc::diffusion_dy_diag`] is available (enables
    /// Milstein schemes).
    fn has_diffusion_jacobian(&self) -> bool {
        false
    }

    /// `∂g_i/∂y_i` into `out`. Only called when
    /// [`SdeFunc::has_diffusion_jacobian`] returns true.
    fn diffusion_dy_diag(&mut self, _t: f64, _y: &[f64], _out: &mut [f64]) {
        unimplemented!("diffusion_dy_diag not provided by this system")
    }

    /// Drift evaluations performed (NFE accounting).
    fn nfe_drift(&self) -> u64;
    /// Diffusion evaluations performed.
    fn nfe_diffusion(&self) -> u64;
}

/// Forward solve of an [`Sde`] at fixed parameters.
///
/// Presents the SDE's coefficients in a *target calculus*: constructed via
/// [`ForwardFunc::new`] it exposes the native form unchanged; via
/// [`ForwardFunc::for_method`] it converts the drift so that the chosen
/// scheme integrates the *same stochastic process* the SDE defines
/// (`b_strat = b_ito − ½σσ'`, and conversely). Without this, e.g. a Heun
/// solve of Itô-native coefficients silently targets a different process —
/// the forward/backward mismatch Figure 2 warns about.
pub struct ForwardFunc<'a, S: Sde + ?Sized> {
    sde: &'a S,
    theta: &'a [f64],
    target: Calculus,
    sig: Vec<f64>,
    dsig: Vec<f64>,
    nfe_f: u64,
    nfe_g: u64,
}

impl<'a, S: Sde + ?Sized> ForwardFunc<'a, S> {
    /// Expose the native coefficients unchanged.
    pub fn new(sde: &'a S, theta: &'a [f64]) -> Self {
        let native = sde.calculus();
        Self::in_calculus(sde, theta, native)
    }

    /// Expose the coefficients converted for `method`'s calculus, so the
    /// solve targets the process the SDE natively defines.
    pub fn for_method(sde: &'a S, theta: &'a [f64], method: crate::solvers::Method) -> Self {
        Self::in_calculus(sde, theta, method.calculus())
    }

    /// Expose the coefficients in an explicit target calculus.
    pub fn in_calculus(sde: &'a S, theta: &'a [f64], target: Calculus) -> Self {
        assert_eq!(
            theta.len(),
            sde.param_dim(),
            "ForwardFunc: theta length {} != param_dim {}",
            theta.len(),
            sde.param_dim()
        );
        let d = sde.state_dim();
        ForwardFunc { sde, theta, target, sig: vec![0.0; d], dsig: vec![0.0; d], nfe_f: 0, nfe_g: 0 }
    }
}

impl<'a, S: Sde + ?Sized> SdeFunc for ForwardFunc<'a, S> {
    fn dim(&self) -> usize {
        self.sde.state_dim()
    }

    fn calculus(&self) -> Calculus {
        self.target
    }

    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_f += 1;
        self.sde.drift(t, y, self.theta, out);
        let native = self.sde.calculus();
        if native != self.target {
            // ±½ σ σ' drift correction (diagonal noise).
            let d = self.sde.state_dim();
            self.sde.diffusion(t, y, self.theta, &mut self.sig);
            self.sde.diffusion_dz_diag(t, y, self.theta, &mut self.dsig);
            let sign = match (native, self.target) {
                (Calculus::Ito, Calculus::Stratonovich) => -0.5,
                (Calculus::Stratonovich, Calculus::Ito) => 0.5,
                _ => unreachable!(),
            };
            for i in 0..d {
                out[i] += sign * self.sig[i] * self.dsig[i];
            }
        }
    }

    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_g += 1;
        self.sde.diffusion(t, y, self.theta, out);
    }

    fn has_diffusion_jacobian(&self) -> bool {
        true
    }

    fn diffusion_dy_diag(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.sde.diffusion_dz_diag(t, y, self.theta, out);
    }

    fn nfe_drift(&self) -> u64 {
        self.nfe_f
    }

    fn nfe_diffusion(&self) -> u64 {
        self.nfe_g
    }
}
