//! Time-series datasets for the §7.2/§7.3 experiments.
//!
//! * [`gbm`] — 1-d geometric Brownian motion, 1024 series observed every
//!   0.02 on [0,1], Gaussian observation noise 0.01 (App. 9.9.1).
//! * [`lorenz`] — 3-d stochastic Lorenz attractor, 1024 series observed
//!   every 0.025 on [0,1], normalized per dimension, noise 0.01
//!   (App. 9.9.2).
//! * [`mocap`] — a synthetic 50-dimensional walking-gait generator standing
//!   in for the CMU subject-35 dataset (DESIGN.md §3 documents the
//!   substitution): 23 sequences of 300 frames, 16/3/4 split.
//!
//! All generators are deterministic in their [`PrngKey`].

pub mod gbm;
pub mod lorenz;
pub mod mocap;
pub mod timeseries;

pub use timeseries::{Batch, TimeSeriesDataset};
