//! Geometric Brownian motion dataset (App. 9.9.1).
//!
//! Ground truth: `dX = μX dt + σX dW`, μ=1, σ=0.5, `x0 = 0.1 + ε`,
//! `ε ~ N(0, 0.03²)`; 1024 series observed at intervals of 0.02 on [0, 1];
//! Gaussian observation noise with std 0.01.

use super::timeseries::TimeSeriesDataset;
use crate::prng::PrngKey;

/// Configuration for the GBM dataset generator.
#[derive(Clone, Copy, Debug)]
pub struct GbmConfig {
    pub mu: f64,
    pub sigma: f64,
    pub x0_mean: f64,
    pub x0_std: f64,
    pub n_series: usize,
    pub dt_obs: f64,
    pub t1: f64,
    pub obs_noise: f64,
}

impl Default for GbmConfig {
    fn default() -> Self {
        GbmConfig {
            mu: 1.0,
            sigma: 0.5,
            x0_mean: 0.1,
            x0_std: 0.03,
            n_series: 1024,
            dt_obs: 0.02,
            t1: 1.0,
            obs_noise: 0.01,
        }
    }
}

/// Generate the dataset using the exact strong solution (no discretization
/// error in the ground truth): `X_t = x0 exp((μ−σ²/2)t + σW_t)` with `W`
/// sampled on the observation grid.
pub fn generate(key: PrngKey, cfg: &GbmConfig) -> TimeSeriesDataset {
    let n_obs = (cfg.t1 / cfg.dt_obs).round() as usize + 1;
    let times: Vec<f64> = (0..n_obs).map(|k| k as f64 * cfg.dt_obs).collect();
    let mut values = vec![0.0; cfg.n_series * n_obs];

    let drift = cfg.mu - 0.5 * cfg.sigma * cfg.sigma;
    for s in 0..cfg.n_series {
        let ks = key.fold_in(s as u64);
        let (kx, kw) = ks.split();
        let x0 = cfg.x0_mean + cfg.x0_std * kx.normal(0);
        let mut w = 0.0;
        for (k, &t) in times.iter().enumerate() {
            if k > 0 {
                w += cfg.dt_obs.sqrt() * kw.normal(k as u64);
            }
            values[s * n_obs + k] = x0 * (drift * t + cfg.sigma * w).exp();
        }
    }
    let mut ds = TimeSeriesDataset::new(times, 1, cfg.n_series, values);
    ds.corrupt(key.fold_in(u64::MAX - 1), cfg.obs_noise);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_spec() {
        let ds = generate(PrngKey::from_seed(1), &GbmConfig::default());
        assert_eq!(ds.n_series, 1024);
        assert_eq!(ds.dim, 1);
        assert_eq!(ds.n_times(), 51);
        assert!((ds.times[1] - 0.02).abs() < 1e-12);
    }

    #[test]
    fn moments_match_gbm_law() {
        // E[X_t] = x0 e^{μt}. At t=1 with μ=1, x0≈0.1: mean ≈ 0.272.
        let ds = generate(PrngKey::from_seed(2), &GbmConfig { n_series: 4096, ..Default::default() });
        let k_end = ds.n_times() - 1;
        let mean: f64 =
            (0..ds.n_series).map(|s| ds.obs(s, k_end)[0]).sum::<f64>() / ds.n_series as f64;
        let expect = 0.1 * 1.0f64.exp();
        assert!(
            (mean - expect).abs() < 0.02 * expect + 0.01,
            "terminal mean {mean} vs {expect}"
        );
    }

    #[test]
    fn deterministic_in_key() {
        let cfg = GbmConfig { n_series: 8, ..Default::default() };
        let a = generate(PrngKey::from_seed(3), &cfg);
        let b = generate(PrngKey::from_seed(3), &cfg);
        assert_eq!(a.series(5), b.series(5));
    }

    #[test]
    fn positivity_mostly_preserved() {
        // GBM is positive; with 0.01 observation noise almost all values
        // stay positive.
        let ds = generate(PrngKey::from_seed(4), &GbmConfig { n_series: 64, ..Default::default() });
        let total = ds.n_series * ds.n_times();
        let neg = (0..ds.n_series)
            .flat_map(|s| (0..ds.n_times()).map(move |k| (s, k)))
            .filter(|&(s, k)| ds.obs(s, k)[0] < 0.0)
            .count();
        assert!(neg < total / 20, "{neg}/{total} negative");
    }
}
