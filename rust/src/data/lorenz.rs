//! Stochastic Lorenz attractor dataset (App. 9.9.2).
//!
//! Ground truth: the [`crate::sde::lorenz::StochasticLorenz`] SDE with
//! σ=10, ρ=28, β=8/3, α=(0.15, 0.15, 0.15); `(x0,y0,z0) ~ N(0,I)`;
//! 1024 series observed at intervals of 0.025 on [0,1]; normalized per
//! dimension; Gaussian observation noise 0.01.

use super::timeseries::TimeSeriesDataset;
use crate::api::{SaveAt, SdeProblem, SolveOptions, StepControl};
use crate::prng::PrngKey;
use crate::sde::lorenz::{paper_theta, StochasticLorenz};
use crate::runtime::ExecConfig;
use crate::solvers::Method;

/// Configuration for the Lorenz dataset generator.
#[derive(Clone, Copy, Debug)]
pub struct LorenzConfig {
    pub n_series: usize,
    pub dt_obs: f64,
    pub t1: f64,
    pub obs_noise: f64,
    /// Simulation sub-steps between observations (ground truth accuracy).
    pub substeps: usize,
    pub normalize: bool,
}

impl Default for LorenzConfig {
    fn default() -> Self {
        LorenzConfig {
            n_series: 1024,
            dt_obs: 0.025,
            t1: 1.0,
            obs_noise: 0.01,
            substeps: 20,
            normalize: true,
        }
    }
}

/// Generate the dataset by integrating the Lorenz SDE with Heun at
/// `substeps × n_obs` resolution and sampling at observation times.
pub fn generate(key: PrngKey, cfg: &LorenzConfig) -> TimeSeriesDataset {
    let n_obs = (cfg.t1 / cfg.dt_obs).round() as usize + 1;
    let times: Vec<f64> = (0..n_obs).map(|k| k as f64 * cfg.dt_obs).collect();
    let theta = paper_theta();
    let sde = StochasticLorenz;
    let n_steps = (n_obs - 1) * cfg.substeps;
    let opts = SolveOptions {
        method: Method::Heun,
        step: StepControl::Steps(n_steps),
        save: SaveAt::Dense,
        exec: ExecConfig::default(),
    };

    // One problem per series, each on its own Brownian stream; solved via
    // the batch API, which chunks the series across threads and advances
    // each chunk's paths together on the batched SoA kernel
    // (ground-truth generation is the dominant cost of dataset
    // construction).
    let probs: Vec<(Vec<f64>, PrngKey)> = (0..cfg.n_series)
        .map(|s| {
            let (kx, kw) = key.fold_in(s as u64).split();
            let mut z0 = [0.0; 3];
            kx.fill_normal(0, &mut z0);
            (z0.to_vec(), kw)
        })
        .collect();
    let problems: Vec<SdeProblem<'_, StochasticLorenz>> = probs
        .iter()
        .map(|(z0, kw)| SdeProblem::new(&sde, z0, (0.0, cfg.t1)).params(&theta).key(*kw))
        .collect();
    let sols = crate::api::solve_batch(&problems, &opts);

    let mut values = vec![0.0; cfg.n_series * n_obs * 3];
    for (s, sol) in sols.iter().enumerate() {
        for k in 0..n_obs {
            let src = k * cfg.substeps * 3;
            values[(s * n_obs + k) * 3..(s * n_obs + k + 1) * 3]
                .copy_from_slice(&sol.states[src..src + 3]);
        }
    }

    let mut ds = TimeSeriesDataset::new(times, 3, cfg.n_series, values);
    if cfg.normalize {
        ds.normalize();
    }
    ds.corrupt(key.fold_in(u64::MAX - 2), cfg.obs_noise);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> LorenzConfig {
        LorenzConfig { n_series: 32, substeps: 10, ..Default::default() }
    }

    #[test]
    fn shapes_match_paper_spec() {
        let ds = generate(PrngKey::from_seed(1), &small_cfg());
        assert_eq!(ds.dim, 3);
        assert_eq!(ds.n_times(), 41);
        assert!((ds.times[1] - 0.025).abs() < 1e-12);
    }

    #[test]
    fn normalization_applied() {
        let ds = generate(PrngKey::from_seed(2), &small_cfg());
        assert!(ds.norm.is_some());
        // Normalized data should be O(1).
        let max = (0..ds.n_series)
            .flat_map(|s| ds.series(s).iter().copied().collect::<Vec<_>>())
            .fold(0.0f64, |m, v| m.max(v.abs()));
        assert!(max < 10.0, "normalized data too large: {max}");
    }

    #[test]
    fn trajectories_diverge_across_series() {
        // Chaotic + stochastic: different series must differ.
        let ds = generate(PrngKey::from_seed(3), &small_cfg());
        let a = ds.series(0);
        let b = ds.series(1);
        let diff: f64 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "series suspiciously similar");
    }

    #[test]
    fn deterministic_in_key() {
        let a = generate(PrngKey::from_seed(4), &small_cfg());
        let b = generate(PrngKey::from_seed(4), &small_cfg());
        assert_eq!(a.series(7), b.series(7));
    }
}
