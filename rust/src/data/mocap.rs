//! Synthetic 50-dimensional motion-capture generator (Table 2 substitute).
//!
//! The paper evaluates on 23 walking sequences of CMU mocap subject 35
//! (50-d joint-angle features, 300 frames, 16/3/4 train/val/test split,
//! preprocessing of Wang et al. 2007). That dataset is not available here,
//! so this module synthesizes a workload with the same *statistical
//! shape* (DESIGN.md §3):
//!
//! * 50 channels driven by a low-dimensional latent gait cycle — a phase
//!   oscillator with per-sequence frequency and per-sequence random mixing
//!   of the first three harmonics into each channel (walking data is
//!   quasi-periodic and strongly low-rank);
//! * slow stochastic drift of the gait frequency and amplitude within a
//!   sequence (an OU process each) — the within-sequence stochasticity
//!   that motivates an SDE prior over an ODE;
//! * per-channel offsets and scales shared across sequences (skeleton
//!   geometry), plus observation noise.
//!
//! The reproducible claim of Table 2 is the *ordering* — latent SDE beats
//! latent ODE and simpler baselines on held-out future-frame MSE — not the
//! absolute numbers, which are dataset-specific.

use super::timeseries::TimeSeriesDataset;
use crate::prng::PrngKey;

/// Configuration of the synthetic mocap generator.
#[derive(Clone, Copy, Debug)]
pub struct MocapConfig {
    pub n_channels: usize,
    pub n_sequences: usize,
    pub n_frames: usize,
    /// Frame period in "seconds" (arbitrary unit used as SDE time).
    pub dt: f64,
    /// Latent harmonics mixed into channels.
    pub n_harmonics: usize,
    /// Base gait angular frequency and its across-sequence jitter.
    pub omega0: f64,
    pub omega_jitter: f64,
    /// OU mean-reversion and noise for within-sequence frequency drift.
    pub freq_ou_kappa: f64,
    pub freq_ou_sigma: f64,
    /// OU noise for amplitude drift.
    pub amp_ou_sigma: f64,
    pub obs_noise: f64,
}

impl Default for MocapConfig {
    fn default() -> Self {
        MocapConfig {
            n_channels: 50,
            n_sequences: 23,
            n_frames: 300,
            dt: 0.01,
            n_harmonics: 3,
            omega0: 2.0 * std::f64::consts::PI * 1.0, // ~1 gait cycle / s
            omega_jitter: 0.15,
            freq_ou_kappa: 2.0,
            freq_ou_sigma: 0.4,
            amp_ou_sigma: 0.25,
            obs_noise: 0.05,
        }
    }
}

/// The paper's split sizes: 16 train / 3 val / 4 test.
pub const SPLIT: (usize, usize, usize) = (16, 3, 4);

/// Generate the dataset. Channel mixing weights/offsets are shared across
/// sequences (same "skeleton"); phase, frequency drift, and amplitude
/// drift vary per sequence.
pub fn generate(key: PrngKey, cfg: &MocapConfig) -> TimeSeriesDataset {
    let (k_skel, k_seq) = key.split();
    let c = cfg.n_channels;
    let h = cfg.n_harmonics;

    // Skeleton: per-channel harmonic weights (sin and cos), offset, scale.
    let mut weights = vec![0.0; c * h * 2];
    k_skel.fill_normal(0, &mut weights);
    let mut offsets = vec![0.0; c];
    k_skel.fold_in(1).fill_normal(0, &mut offsets);
    let mut scales = vec![0.0; c];
    k_skel.fold_in(2).fill_normal(0, &mut scales);
    for s in scales.iter_mut() {
        *s = 0.5 + 0.5 / (1.0 + (-*s).exp()); // in (0.5, 1.0)
    }

    let times: Vec<f64> = (0..cfg.n_frames).map(|k| k as f64 * cfg.dt).collect();
    let mut values = vec![0.0; cfg.n_sequences * cfg.n_frames * c];

    for s in 0..cfg.n_sequences {
        let ks = k_seq.fold_in(s as u64);
        let (k_init, k_noise) = ks.split();
        // Per-sequence gait parameters.
        let omega = cfg.omega0 * (1.0 + cfg.omega_jitter * k_init.normal(0));
        let mut phase = 2.0 * std::f64::consts::PI * k_init.uniform(1);
        let mut freq_dev = 0.0; // OU around 0, multiplies omega
        let mut amp_dev: f64 = 0.0; // OU around 0, add to log-amplitude

        for f in 0..cfg.n_frames {
            // Euler–Maruyama for the two OU processes + phase integration.
            if f > 0 {
                let (e1, e2) = k_noise.normal_pair(f as u64);
                freq_dev += -cfg.freq_ou_kappa * freq_dev * cfg.dt
                    + cfg.freq_ou_sigma * cfg.dt.sqrt() * e1;
                amp_dev += -cfg.freq_ou_kappa * amp_dev * cfg.dt
                    + cfg.amp_ou_sigma * cfg.dt.sqrt() * e2;
                phase += omega * (1.0 + freq_dev) * cfg.dt;
            }
            let amp = amp_dev.exp();
            let row = &mut values[(s * cfg.n_frames + f) * c..(s * cfg.n_frames + f + 1) * c];
            for ch in 0..c {
                let mut v = offsets[ch];
                for m in 0..h {
                    let w_sin = weights[(ch * h + m) * 2];
                    let w_cos = weights[(ch * h + m) * 2 + 1];
                    let arg = (m + 1) as f64 * phase;
                    v += amp * scales[ch] * (w_sin * arg.sin() + w_cos * arg.cos());
                }
                row[ch] = v;
            }
        }
    }

    let mut ds = TimeSeriesDataset::new(times, c, cfg.n_sequences, values);
    ds.normalize();
    ds.corrupt(key.fold_in(u64::MAX - 3), cfg.obs_noise);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MocapConfig {
        MocapConfig { n_sequences: 6, n_frames: 100, ..Default::default() }
    }

    #[test]
    fn shapes() {
        let ds = generate(PrngKey::from_seed(1), &cfg());
        assert_eq!(ds.dim, 50);
        assert_eq!(ds.n_series, 6);
        assert_eq!(ds.n_times(), 100);
    }

    #[test]
    fn channels_are_correlated_low_rank() {
        // The latent gait drives all channels: average |corr| between the
        // first few channels should be clearly nonzero.
        let ds = generate(PrngKey::from_seed(2), &cfg());
        let n = ds.n_times();
        let col = |ch: usize| -> Vec<f64> { (0..n).map(|k| ds.obs(0, k)[ch]).collect() };
        let corr = |a: &[f64], b: &[f64]| -> f64 {
            let ma = a.iter().sum::<f64>() / a.len() as f64;
            let mb = b.iter().sum::<f64>() / b.len() as f64;
            let num: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
            let da: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum::<f64>().sqrt();
            let db: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum::<f64>().sqrt();
            num / (da * db).max(1e-12)
        };
        let mut total = 0.0;
        let mut count = 0;
        for i in 0..5 {
            for j in (i + 1)..5 {
                total += corr(&col(i), &col(j)).abs();
                count += 1;
            }
        }
        assert!(total / count as f64 > 0.2, "channels look independent");
    }

    #[test]
    fn sequences_differ_but_share_structure() {
        let ds = generate(PrngKey::from_seed(3), &cfg());
        let a = ds.series(0);
        let b = ds.series(1);
        let diff: f64 =
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64;
        assert!(diff > 0.05, "sequences identical?");
    }

    #[test]
    fn quasi_periodicity() {
        // Autocorrelation of a channel at one gait period should be high.
        let c = cfg();
        let ds = generate(PrngKey::from_seed(4), &c);
        let n = ds.n_times();
        let period_frames = (2.0 * std::f64::consts::PI / c.omega0 / c.dt).round() as usize;
        if period_frames < n {
            let col: Vec<f64> = (0..n).map(|k| ds.obs(2, k)[7]).collect();
            let m = col.iter().sum::<f64>() / n as f64;
            let var: f64 = col.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / n as f64;
            let mut ac = 0.0;
            for k in 0..n - period_frames {
                ac += (col[k] - m) * (col[k + period_frames] - m);
            }
            ac /= (n - period_frames) as f64 * var.max(1e-12);
            assert!(ac > 0.3, "no periodic structure: autocorr {ac}");
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(PrngKey::from_seed(5), &cfg());
        let b = generate(PrngKey::from_seed(5), &cfg());
        assert_eq!(a.series(3), b.series(3));
    }
}
