//! Containers for regularly/irregularly sampled multivariate time series.

use crate::prng::PrngKey;

/// A dataset of `n_series` sequences observed at shared times.
///
/// Values are stored row-major as `(series, time, dim)`.
#[derive(Clone, Debug)]
pub struct TimeSeriesDataset {
    pub times: Vec<f64>,
    pub dim: usize,
    pub n_series: usize,
    values: Vec<f64>,
    /// Per-dimension normalization applied at construction: `x_norm =
    /// (x − mean) / std`. Identity if `None`.
    pub norm: Option<(Vec<f64>, Vec<f64>)>,
}

/// A view of selected series indices (one minibatch).
#[derive(Clone, Debug)]
pub struct Batch<'a> {
    pub dataset: &'a TimeSeriesDataset,
    pub indices: Vec<usize>,
}

impl TimeSeriesDataset {
    pub fn new(times: Vec<f64>, dim: usize, n_series: usize, values: Vec<f64>) -> Self {
        assert_eq!(values.len(), times.len() * dim * n_series, "value buffer size mismatch");
        TimeSeriesDataset { times, dim, n_series, values, norm: None }
    }

    /// Number of observation times.
    pub fn n_times(&self) -> usize {
        self.times.len()
    }

    /// The observation vector of series `s` at time index `k`.
    pub fn obs(&self, s: usize, k: usize) -> &[f64] {
        let stride_t = self.dim;
        let stride_s = self.n_times() * self.dim;
        &self.values[s * stride_s + k * stride_t..s * stride_s + k * stride_t + self.dim]
    }

    /// Full sequence of series `s` as a `(n_times, dim)` row-major slice.
    pub fn series(&self, s: usize) -> &[f64] {
        let stride_s = self.n_times() * self.dim;
        &self.values[s * stride_s..(s + 1) * stride_s]
    }

    /// Normalize each dimension to zero mean / unit std across the whole
    /// dataset (App. 9.9.2 normalizes the Lorenz data this way).
    pub fn normalize(&mut self) {
        let d = self.dim;
        let n = self.values.len() / d;
        let mut mean = vec![0.0; d];
        let mut std = vec![0.0; d];
        for (i, v) in self.values.iter().enumerate() {
            mean[i % d] += v;
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        for (i, v) in self.values.iter().enumerate() {
            let c = v - mean[i % d];
            std[i % d] += c * c;
        }
        for s in std.iter_mut() {
            *s = (*s / n as f64).sqrt().max(1e-12);
        }
        for (i, v) in self.values.iter_mut().enumerate() {
            *v = (*v - mean[i % d]) / std[i % d];
        }
        self.norm = Some((mean, std));
    }

    /// Add i.i.d. Gaussian observation noise of the given std.
    pub fn corrupt(&mut self, key: PrngKey, noise_std: f64) {
        let mut buf = vec![0.0; self.values.len()];
        key.fill_normal(0, &mut buf);
        for (v, n) in self.values.iter_mut().zip(&buf) {
            *v += noise_std * n;
        }
    }

    /// Deterministically shuffle indices and split into three datasets'
    /// index lists of the given sizes.
    pub fn split_indices(
        &self,
        key: PrngKey,
        n_train: usize,
        n_val: usize,
        n_test: usize,
    ) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
        assert!(n_train + n_val + n_test <= self.n_series, "split exceeds dataset");
        let mut idx: Vec<usize> = (0..self.n_series).collect();
        // Fisher–Yates with our PRNG.
        for i in (1..idx.len()).rev() {
            let j = (key.uniform(i as u64) * (i + 1) as f64) as usize;
            idx.swap(i, j.min(i));
        }
        let train = idx[..n_train].to_vec();
        let val = idx[n_train..n_train + n_val].to_vec();
        let test = idx[n_train + n_val..n_train + n_val + n_test].to_vec();
        (train, val, test)
    }

    /// Iterate minibatches of `batch_size` over the given indices in a
    /// deterministic per-epoch shuffled order.
    pub fn minibatches<'a>(
        &'a self,
        indices: &[usize],
        batch_size: usize,
        key: PrngKey,
        epoch: u64,
    ) -> Vec<Batch<'a>> {
        let mut order = indices.to_vec();
        let k = key.fold_in(epoch);
        for i in (1..order.len()).rev() {
            let j = (k.uniform(i as u64) * (i + 1) as f64) as usize;
            order.swap(i, j.min(i));
        }
        order
            .chunks(batch_size)
            .map(|c| Batch { dataset: self, indices: c.to_vec() })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> TimeSeriesDataset {
        // 2 series, 3 times, dim 2: values = series*100 + time*10 + dim.
        let mut vals = Vec::new();
        for s in 0..2 {
            for t in 0..3 {
                for d in 0..2 {
                    vals.push((s * 100 + t * 10 + d) as f64);
                }
            }
        }
        TimeSeriesDataset::new(vec![0.0, 0.5, 1.0], 2, 2, vals)
    }

    #[test]
    fn indexing_layout() {
        let ds = toy();
        assert_eq!(ds.obs(0, 0), &[0.0, 1.0]);
        assert_eq!(ds.obs(1, 2), &[120.0, 121.0]);
        assert_eq!(ds.series(0).len(), 6);
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let mut ds = toy();
        ds.normalize();
        let d = ds.dim;
        let n = ds.values.len() / d;
        for dim in 0..d {
            let vals: Vec<f64> = ds.values.iter().skip(dim).step_by(d).copied().collect();
            let mean: f64 = vals.iter().sum::<f64>() / n as f64;
            let var: f64 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-10, "dim {dim} mean {mean}");
            assert!((var - 1.0).abs() < 1e-10, "dim {dim} var {var}");
        }
    }

    #[test]
    fn split_is_disjoint_and_deterministic() {
        let mut vals = vec![0.0; 10 * 3 * 2];
        for (i, v) in vals.iter_mut().enumerate() {
            *v = i as f64;
        }
        let ds = TimeSeriesDataset::new(vec![0.0, 0.5, 1.0], 2, 10, vals);
        let key = PrngKey::from_seed(5);
        let (tr, va, te) = ds.split_indices(key, 6, 2, 2);
        let (tr2, _, _) = ds.split_indices(key, 6, 2, 2);
        assert_eq!(tr, tr2);
        let mut all: Vec<usize> = tr.iter().chain(&va).chain(&te).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 10, "split indices overlap");
    }

    #[test]
    fn minibatches_cover_all_indices() {
        let ds = toy();
        let batches = ds.minibatches(&[0, 1], 1, PrngKey::from_seed(1), 0);
        assert_eq!(batches.len(), 2);
        let mut seen: Vec<usize> = batches.iter().flat_map(|b| b.indices.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    fn corrupt_changes_values_modestly() {
        let mut ds = toy();
        let before = ds.series(0).to_vec();
        ds.corrupt(PrngKey::from_seed(3), 0.01);
        let after = ds.series(0);
        let max_delta = before
            .iter()
            .zip(after)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_delta > 0.0 && max_delta < 0.1);
    }
}
