//! Wall-clock timing with warmup/repeat semantics (criterion substitute —
//! see DESIGN.md §3: the vendored crate set has no criterion, so bench
//! targets use this harness with `harness = false`).

use std::time::Instant;

use super::stats::OnlineStats;

/// A simple stopwatch accumulating split times.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }

    /// Seconds elapsed since construction/restart.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `reps` measured
/// runs; returns per-run statistics in seconds. A `black_box`-style sink
/// prevents the optimizer from deleting the work — callers should return
/// something data-dependent from `f`.
pub fn bench<F: FnMut() -> f64>(warmup: usize, reps: usize, mut f: F) -> OnlineStats {
    let mut sink = 0.0;
    for _ in 0..warmup {
        sink += f();
    }
    let mut stats = OnlineStats::new();
    for _ in 0..reps {
        let sw = Stopwatch::new();
        sink += f();
        stats.push(sw.elapsed_s());
    }
    // Keep the sink alive.
    if sink.is_nan() {
        eprintln!("bench sink: {sink}");
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_time() {
        let sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let t = sw.elapsed_s();
        assert!(t >= 0.009, "elapsed {t}");
    }

    #[test]
    fn bench_runs_expected_count() {
        let mut count = 0;
        let stats = bench(2, 5, || {
            count += 1;
            count as f64
        });
        assert_eq!(count, 7);
        assert_eq!(stats.count(), 5);
    }
}
