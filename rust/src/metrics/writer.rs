//! CSV / JSONL output for figure regeneration (bench harnesses write their
//! series under `bench_out/` so plots can be made externally).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Minimal CSV writer (no quoting needs arise: we write numbers and
/// simple identifiers only).
pub struct CsvWriter {
    out: BufWriter<File>,
    n_cols: usize,
}

impl CsvWriter {
    /// Create (truncating) a CSV file with the given header.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, n_cols: header.len() })
    }

    /// Open for appending when the file already exists non-empty (its
    /// header is assumed present), otherwise create it with the header.
    /// Used by resumed training runs so the earlier segment of the loss
    /// curve survives instead of being truncated.
    pub fn append_or_create<P: AsRef<Path>>(
        path: P,
        header: &[&str],
    ) -> std::io::Result<CsvWriter> {
        let has_content = path.as_ref().exists()
            && fs::metadata(path.as_ref()).map(|m| m.len() > 0).unwrap_or(false);
        if !has_content {
            return CsvWriter::create(path, header);
        }
        let out = BufWriter::new(fs::OpenOptions::new().append(true).open(path)?);
        Ok(CsvWriter { out, n_cols: header.len() })
    }

    /// Write a row of mixed string/number fields (pre-formatted).
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.n_cols, "CSV row width mismatch");
        writeln!(self.out, "{}", fields.join(","))
    }

    /// Convenience for all-numeric rows.
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Minimal JSONL writer for structured records (hand-rolled: serde is not
/// in the vendored crate set — DESIGN.md §3).
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create<P: AsRef<Path>>(path: P) -> std::io::Result<JsonlWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlWriter { out: BufWriter::new(File::create(path)?) })
    }

    /// Write one record of key/value pairs where values are already JSON
    /// fragments (numbers via [`json_num`], strings via [`json_str`]).
    pub fn record(&mut self, fields: &[(&str, String)]) -> std::io::Result<()> {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("{}:{}", json_str(k), v))
            .collect();
        writeln!(self.out, "{{{}}}", body.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

// The JSON fragment formatters moved to the crate's single JSON module;
// re-exported here so `metrics::writer::{json_str, json_num}` keeps
// working for existing call sites.
pub use super::json::{json_num, json_str};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("sdegrad_test_csv");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row_f64(&[1.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1.5,2\n");
    }

    #[test]
    fn jsonl_escaping() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(2.5), "2.5");
    }

    #[test]
    fn jsonl_record_shape() {
        let dir = std::env::temp_dir().join("sdegrad_test_jsonl");
        let path = dir.join("t.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.record(&[("x", json_num(1.0)), ("name", json_str("hi"))]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "{\"x\":1,\"name\":\"hi\"}\n");
    }
}
