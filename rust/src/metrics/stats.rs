//! Online statistics and summary helpers.

/// Welford online mean/variance accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// 95% confidence half-width based on the t-statistic (Table 2's CI
/// convention). Uses a two-sided t quantile table for small n and the
/// normal 1.96 beyond.
pub fn confidence_interval_95(stats: &OnlineStats) -> f64 {
    let n = stats.count();
    if n < 2 {
        return f64::NAN;
    }
    let dof = (n - 1) as usize;
    // Two-sided 97.5% t quantiles for dof 1..30.
    const T: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    let t = if dof <= 30 { T[dof - 1] } else { 1.96 };
    t * stats.sem()
}

/// Median/quartiles of a sample (Fig 5(a) boxplot statistics).
#[derive(Clone, Copy, Debug)]
pub struct Quartiles {
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
}

impl Quartiles {
    /// Compute from a sample (copies + sorts internally).
    pub fn of(values: &[f64]) -> Quartiles {
        assert!(!values.is_empty(), "Quartiles of empty sample");
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| -> f64 {
            let idx = p * (v.len() - 1) as f64;
            let lo = idx.floor() as usize;
            let hi = idx.ceil() as usize;
            let frac = idx - lo as f64;
            v[lo] * (1.0 - frac) + v[hi] * frac
        };
        Quartiles { q1: q(0.25), median: q(0.5), q3: q(0.75), min: v[0], max: *v.last().unwrap() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn quartiles_of_known_sample() {
        let q = Quartiles::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(q.median, 3.0);
        assert_eq!(q.q1, 2.0);
        assert_eq!(q.q3, 4.0);
        assert_eq!(q.min, 1.0);
        assert_eq!(q.max, 5.0);
    }

    #[test]
    fn ci_reasonable_for_large_n() {
        let mut s = OnlineStats::new();
        for i in 0..100 {
            s.push(i as f64 % 2.0); // alternating 0/1: std ≈ 0.5
        }
        let ci = confidence_interval_95(&s);
        assert!(ci > 0.05 && ci < 0.2, "ci = {ci}");
    }
}
