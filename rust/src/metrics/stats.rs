//! Online statistics and summary helpers.

/// Welford online mean/variance accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// 95% confidence half-width based on the t-statistic (Table 2's CI
/// convention). Uses a two-sided t quantile table for small n and the
/// normal 1.96 beyond.
pub fn confidence_interval_95(stats: &OnlineStats) -> f64 {
    let n = stats.count();
    if n < 2 {
        return f64::NAN;
    }
    let dof = (n - 1) as usize;
    // Two-sided 97.5% t quantiles for dof 1..30.
    const T: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    let t = if dof <= 30 { T[dof - 1] } else { 1.96 };
    t * stats.sem()
}

/// Least-squares fit of `ln y = slope·ln x + intercept` — the estimator
/// behind every empirical convergence order (`error ≈ C·hᵖ` appears as a
/// line of slope `p` in log-log coordinates).
#[derive(Clone, Copy, Debug)]
pub struct LogLogFit {
    pub slope: f64,
    pub intercept: f64,
    /// Points actually used (non-finite or non-positive pairs are
    /// dropped — a Monte-Carlo error estimate can legitimately be 0).
    pub n_used: usize,
}

/// Ordinary least squares on `(ln x, ln y)`. Pairs where either value is
/// non-positive or non-finite are skipped; returns NaN slope when fewer
/// than two usable points remain.
pub fn fit_loglog(x: &[f64], y: &[f64]) -> LogLogFit {
    assert_eq!(x.len(), y.len(), "fit_loglog: length mismatch");
    let pts: Vec<(f64, f64)> = x
        .iter()
        .zip(y)
        .filter(|(&a, &b)| a > 0.0 && b > 0.0 && a.is_finite() && b.is_finite())
        .map(|(&a, &b)| (a.ln(), b.ln()))
        .collect();
    let n = pts.len();
    if n < 2 {
        return LogLogFit { slope: f64::NAN, intercept: f64::NAN, n_used: n };
    }
    let nf = n as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / nf;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / nf;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let slope = sxy / sxx;
    LogLogFit { slope, intercept: my - slope * mx, n_used: n }
}

/// Linear-interpolated percentile of an ascending-sorted sample
/// (`p ∈ [0, 1]`). Shared by [`Quartiles`] and the convergence
/// subsystem's bootstrap confidence intervals.
pub fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    let idx = p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    let frac = idx - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median/quartiles of a sample (Fig 5(a) boxplot statistics).
#[derive(Clone, Copy, Debug)]
pub struct Quartiles {
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
}

impl Quartiles {
    /// Compute from a sample (copies + sorts internally).
    pub fn of(values: &[f64]) -> Quartiles {
        assert!(!values.is_empty(), "Quartiles of empty sample");
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| percentile_of_sorted(&v, p);
        Quartiles { q1: q(0.25), median: q(0.5), q3: q(0.75), min: v[0], max: *v.last().unwrap() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn quartiles_of_known_sample() {
        let q = Quartiles::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(q.median, 3.0);
        assert_eq!(q.q1, 2.0);
        assert_eq!(q.q3, 4.0);
        assert_eq!(q.min, 1.0);
        assert_eq!(q.max, 5.0);
    }

    #[test]
    fn fit_loglog_recovers_exact_power_law() {
        let hs = [0.5, 0.25, 0.125, 0.0625];
        let ys: Vec<f64> = hs.iter().map(|h| 3.0 * h.powf(1.5)).collect();
        let fit = fit_loglog(&hs, &ys);
        assert_eq!(fit.n_used, 4);
        assert!((fit.slope - 1.5).abs() < 1e-12, "slope {}", fit.slope);
        assert!((fit.intercept - 3.0f64.ln()).abs() < 1e-12, "intercept {}", fit.intercept);
    }

    #[test]
    fn fit_loglog_skips_degenerate_points() {
        let hs = [0.5, 0.25, 0.125, 0.0625];
        let ys = [1.0, 0.5, 0.0, f64::NAN]; // two usable points
        let fit = fit_loglog(&hs, &ys);
        assert_eq!(fit.n_used, 2);
        assert!((fit.slope - 1.0).abs() < 1e-12, "slope {}", fit.slope);
        let all_bad = fit_loglog(&hs[..2], &[0.0, -1.0]);
        assert_eq!(all_bad.n_used, 0);
        assert!(all_bad.slope.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_of_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_of_sorted(&v, 1.0), 4.0);
        assert_eq!(percentile_of_sorted(&v, 0.5), 2.5);
    }

    #[test]
    fn ci_reasonable_for_large_n() {
        let mut s = OnlineStats::new();
        for i in 0..100 {
            s.push(i as f64 % 2.0); // alternating 0/1: std ≈ 0.5
        }
        let ci = confidence_interval_95(&s);
        assert!(ci > 0.05 && ci < 0.2, "ci = {ci}");
    }
}
