//! Measurement utilities shared by the trainer and the bench harnesses:
//! online statistics, timers, confidence intervals (Table 2 reports
//! t-statistic 95% CIs), and CSV/JSONL writers for figure data.

pub mod stats;
pub mod timer;
pub mod writer;

pub use stats::{
    confidence_interval_95, fit_loglog, percentile_of_sorted, LogLogFit, OnlineStats, Quartiles,
};
pub use timer::Stopwatch;
pub use writer::{CsvWriter, JsonlWriter};
