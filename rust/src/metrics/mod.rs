//! Measurement utilities shared by the trainer and the bench harnesses:
//! online statistics, timers, confidence intervals (Table 2 reports
//! t-statistic 95% CIs), CSV/JSONL writers for figure data, and the
//! crate's single JSON implementation ([`json`] — emit, scan, parse),
//! shared by the bench artifacts and the serving protocol.

pub mod counters;
pub mod json;
pub mod stats;
pub mod timer;
pub mod writer;

pub use counters::{add_bridge_calls, bridge_calls_total};
pub use json::{json_num, json_str, parse_json, JsonValue};
pub use stats::{
    confidence_interval_95, fit_loglog, percentile_of_sorted, LogLogFit, OnlineStats, Quartiles,
};
pub use timer::Stopwatch;
pub use writer::{CsvWriter, JsonlWriter};
