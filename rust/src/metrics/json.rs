//! The crate's single JSON implementation (hand-rolled: serde is not in
//! the hermetic crate set — DESIGN.md §3).
//!
//! Three layers, shared by every JSON producer/consumer in the crate:
//!
//! * **Emit** — [`json_str`] / [`json_num`] fragment formatters, used by
//!   [`super::writer::JsonlWriter`], the bench harnesses
//!   (`coordinator::bench`), and the serving protocol
//!   (`serve::protocol`). Numbers go through Rust's shortest-roundtrip
//!   `{}` formatting, so emitting and re-parsing an `f64` is exact —
//!   the property the serving subsystem's byte-identical response
//!   contract rests on.
//! * **Scan** — [`json_string_field`] / [`json_number_field`]: flat
//!   field scanners for *our own* emitted formats (`BENCH_*.json`),
//!   where the shape is known and a full parse is overkill.
//! * **Parse** — [`parse_json`] → [`JsonValue`]: a small recursive-
//!   descent parser for untrusted input (serving request bodies), with
//!   a nesting-depth cap so malicious bodies cannot blow the stack.

use std::fmt::Write as _;

/// JSON-escape a string (quotes, backslashes, control chars).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a number as a JSON value (NaN/inf → null). Finite values use
/// shortest-roundtrip formatting: parsing the emitted text recovers the
/// exact same `f64`.
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Scan `block` for `"key": "value"` and return the value. Values we
/// emit are plain identifiers (no escapes), which is all this handles —
/// use [`parse_json`] for untrusted input.
pub fn json_string_field(block: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let at = block.find(&pat)? + pat.len();
    let rest = block[at..].trim_start().strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

/// Scan `block` for `"key": <number>` and parse it. The literal must be
/// a strict JSON number ([`is_strict_json_number`]) — which everything
/// [`json_num`] emits is.
pub fn json_number_field(block: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = block.find(&pat)? + pat.len();
    let rest = block[at..].trim_start();
    let end = rest
        .find(|c: char| c == ',' || c == '}' || c == ']' || c.is_whitespace())
        .unwrap_or(rest.len());
    let lit = &rest[..end];
    if !is_strict_json_number(lit) {
        return None;
    }
    lit.parse().ok()
}

/// Exactly one number of the strict JSON grammar:
/// `-? (0 | [1-9][0-9]*) (\.[0-9]+)? ([eE][+-]?[0-9]+)?`.
///
/// `f64::from_str` accepts a superset over the same byte alphabet —
/// `inf`, `nan`, a leading `+`, leading zeros (`01`), and bare dots
/// (`1.`, `.5`) — so every number literal is routed through this check
/// first to keep the wire format strict JSON.
fn is_strict_json_number(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0;
    if b.first() == Some(&b'-') {
        i += 1;
    }
    match b.get(i) {
        Some(b'0') => i += 1, // no leading zeros: "0" ends the int part
        Some(b'1'..=b'9') => {
            while matches!(b.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => return false,
    }
    if b.get(i) == Some(&b'.') {
        i += 1;
        let frac = i;
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
        if i == frac {
            return false; // "1." — a dot needs digits after it
        }
    }
    if matches!(b.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        let exp = i;
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
        if i == exp {
            return false; // "1e" — an exponent marker needs digits
        }
    }
    i == b.len()
}

/// A parsed JSON value. Objects preserve key order (a `Vec` of pairs —
/// the payloads this crate parses are small, and order preservation
/// keeps canonical re-emission deterministic).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Integer-valued number in `u64` range (exactly representable —
    /// restricted to `< 2^53` so no precision was lost in the `f64`).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(v)
                if v.fract() == 0.0 && *v >= 0.0 && *v < 9_007_199_254_740_992.0 =>
            {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Maximum nesting depth accepted by [`parse_json`] (arrays/objects).
const MAX_DEPTH: usize = 32;

/// Parse one JSON document. Errors carry a byte offset and a short
/// reason. Numbers follow the strict JSON grammar
/// ([`is_strict_json_number`]): `inf`, `nan`, leading `+`, leading
/// zeros, and bare dots are rejected rather than silently coerced.
pub fn parse_json(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(text, bytes, &mut pos, 0)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < bytes.len() && bytes[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, *pos))
    }
}

fn parse_value(
    text: &str,
    bytes: &[u8],
    pos: &mut usize,
    depth: usize,
) -> Result<JsonValue, String> {
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH}"));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(JsonValue::Obj(pairs));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(text, bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let val = parse_value(text, bytes, pos, depth + 1)?;
                pairs.push((key, val));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(JsonValue::Obj(pairs));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(JsonValue::Arr(items));
            }
            loop {
                items.push(parse_value(text, bytes, pos, depth + 1)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(JsonValue::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'"') => parse_string(text, bytes, pos).map(JsonValue::Str),
        Some(b't') if text[*pos..].starts_with("true") => {
            *pos += 4;
            Ok(JsonValue::Bool(true))
        }
        Some(b'f') if text[*pos..].starts_with("false") => {
            *pos += 5;
            Ok(JsonValue::Bool(false))
        }
        Some(b'n') if text[*pos..].starts_with("null") => {
            *pos += 4;
            Ok(JsonValue::Null)
        }
        Some(c) if matches!(c, b'-' | b'0'..=b'9') => {
            let start = *pos;
            while *pos < bytes.len()
                && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let lit = &text[start..*pos];
            if !is_strict_json_number(lit) {
                return Err(format!("bad number at byte {start}"));
            }
            lit.parse::<f64>()
                .map(JsonValue::Num)
                .map_err(|_| format!("bad number at byte {start}"))
        }
        Some(c) => Err(format!("unexpected character '{}' at byte {}", *c as char, *pos)),
    }
}

fn parse_string(text: &str, bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = text
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| format!("bad \\u escape at byte {}", *pos))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape at byte {}", *pos))?;
                        // Surrogate pairs are rejected rather than
                        // combined — our emitters never produce them.
                        let c = char::from_u32(code)
                            .ok_or_else(|| format!("invalid \\u code point at byte {}", *pos))?;
                        out.push(c);
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => {
                return Err(format!("raw control character at byte {}", *pos));
            }
            Some(_) => {
                // Consume one full UTF-8 scalar (the input is a &str, so
                // boundaries are valid).
                let s = &text[*pos..];
                let ch = s.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_round_trips() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(2.5), "2.5");
        let parsed = parse_json(&json_str("a\"b\\c\nπ\t")).unwrap();
        assert_eq!(parsed, JsonValue::Str("a\"b\\c\nπ\t".to_string()));
    }

    #[test]
    fn numbers_round_trip_exactly() {
        for v in [0.1, -1.5e-300, 1.0 / 3.0, 123456789.123456789, f64::MIN_POSITIVE, -0.0] {
            let emitted = json_num(v);
            let back = parse_json(&emitted).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v} → {emitted} → {back}");
        }
    }

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"model": "m", "seed": 7, "times": [0, 0.5, 1.0],
                      "obs": [[1, 2], [3, 4]], "flag": true, "none": null}"#;
        let v = parse_json(doc).unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("m"));
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(7));
        let times = v.get("times").unwrap().as_array().unwrap();
        assert_eq!(times.len(), 3);
        assert_eq!(times[1].as_f64(), Some(0.5));
        let obs = v.get("obs").unwrap().as_array().unwrap();
        assert_eq!(obs[1].as_array().unwrap()[0].as_f64(), Some(3.0));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&JsonValue::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1, 2",
            "{\"a\" 1}",
            "{\"a\": 1,}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\": 1} trailing",
            "\"bad \\q escape\"",
            "nan",
        ] {
            assert!(parse_json(bad).is_err(), "accepted: {bad:?}");
        }
    }

    /// The strict number grammar: `f64::from_str`'s extras must not
    /// leak through (`inf`, leading `+`, leading zeros, bare dots).
    #[test]
    fn number_grammar_is_strict_json() {
        for bad in [
            "inf", "-inf", "Infinity", "nan", "+1", "1.", ".5", "-.5", "01", "-01", "0x1",
            "1e", "1e+", "1.e5", "--1", "1.2.3", "-",
        ] {
            assert!(parse_json(bad).is_err(), "accepted non-JSON number: {bad:?}");
            assert!(
                parse_json(&format!("[{bad}]")).is_err(),
                "accepted non-JSON number in array: {bad:?}"
            );
        }
        for (good, want) in [
            ("0", 0.0),
            ("-0", -0.0),
            ("10", 10.0),
            ("0.5", 0.5),
            ("1e5", 1e5),
            ("1E5", 1e5),
            ("-0.5e-3", -0.5e-3),
            ("2.25e+2", 225.0),
        ] {
            let got = parse_json(good).unwrap().as_f64().unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "{good}");
        }
        // The field scanner applies the same grammar.
        assert_eq!(json_number_field("{\"v\": 01}", "v"), None);
        assert_eq!(json_number_field("{\"v\": inf}", "v"), None);
        assert_eq!(json_number_field("{\"v\": -2.5e-1}", "v"), Some(-0.25));
    }

    #[test]
    fn rejects_deep_nesting() {
        let mut doc = String::new();
        for _ in 0..200 {
            doc.push('[');
        }
        doc.push('1');
        for _ in 0..200 {
            doc.push(']');
        }
        assert!(parse_json(&doc).is_err());
    }

    #[test]
    fn u64_guardrails() {
        assert_eq!(parse_json("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse_json("-1").unwrap().as_u64(), None);
        assert_eq!(parse_json("1.5").unwrap().as_u64(), None);
        assert_eq!(parse_json("1e300").unwrap().as_u64(), None);
    }

    #[test]
    fn field_scanners_match_emitted_shape() {
        let block = "{\"problem\": \"gbm_d10\", \"value_per_sec\": 123.5, \"steps\": 200}";
        assert_eq!(json_string_field(block, "problem").as_deref(), Some("gbm_d10"));
        assert_eq!(json_number_field(block, "value_per_sec"), Some(123.5));
        assert_eq!(json_number_field(block, "steps"), Some(200.0));
        assert_eq!(json_string_field(block, "missing"), None);
        assert_eq!(json_number_field(block, "missing"), None);
    }
}
