//! Process-wide monotone counters surfaced by the server's
//! `GET /metrics` endpoint (`serve/server.rs`).
//!
//! The crate's instrumentation is otherwise per-object — each
//! [`crate::brownian::VirtualBrownianTree`] counts its own bridge draws,
//! each batcher shard its own queue traffic. A serving process wants the
//! *process totals* too (how much Brownian work has the whole fleet of
//! engine calls done?), so dropped trees flush their lifetime draw count
//! here. Counters are monotone by construction: relaxed `fetch_add` of
//! non-negative deltas, never reset.

use std::sync::atomic::{AtomicU64, Ordering};

static BRIDGE_CALLS: AtomicU64 = AtomicU64::new(0);

/// Add `n` Brownian-bridge draws to the process-wide total. Called from
/// `VirtualBrownianTree`'s drop glue with the tree's unflushed delta —
/// relaxed ordering is enough for a statistics counter.
pub fn add_bridge_calls(n: u64) {
    if n > 0 {
        BRIDGE_CALLS.fetch_add(n, Ordering::Relaxed);
    }
}

/// Lifetime Brownian-bridge draws across every dropped tree in this
/// process. Monotone; live trees' in-progress draws appear once they
/// drop.
pub fn bridge_calls_total() -> u64 {
    BRIDGE_CALLS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bridge_counter_is_monotone_under_adds() {
        let before = bridge_calls_total();
        add_bridge_calls(0); // no-op delta
        assert_eq!(bridge_calls_total(), before);
        add_bridge_calls(3);
        add_bridge_calls(5);
        // Other tests drop trees concurrently, so assert a lower bound,
        // not equality.
        assert!(bridge_calls_total() >= before + 8);
    }
}
