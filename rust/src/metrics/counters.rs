//! Process-wide monotone counters surfaced by the server's
//! `GET /metrics` endpoint (`serve/server.rs`).
//!
//! Since the observability subsystem landed, the actual storage lives in
//! the central registry ([`crate::obs::registry`]) under the name
//! `brownian.bridge_calls`; the functions here are thin delegating shims
//! kept for the existing call sites and test pins. The semantics are
//! unchanged: monotone by construction — relaxed `fetch_add` of
//! non-negative deltas, never reset — and dropped
//! [`crate::brownian::VirtualBrownianTree`]s flush their lifetime draw
//! count here so a serving process can report *process totals*.

use std::sync::OnceLock;

use crate::obs;

fn bridge_calls() -> &'static obs::Counter {
    static COUNTER: OnceLock<obs::Counter> = OnceLock::new();
    COUNTER.get_or_init(|| obs::counter("brownian.bridge_calls"))
}

/// Add `n` Brownian-bridge draws to the process-wide total. Called from
/// `VirtualBrownianTree`'s drop glue with the tree's unflushed delta —
/// relaxed ordering is enough for a statistics counter.
pub fn add_bridge_calls(n: u64) {
    bridge_calls().add(n);
}

/// Lifetime Brownian-bridge draws across every dropped tree in this
/// process. Monotone; live trees' in-progress draws appear once they
/// drop.
pub fn bridge_calls_total() -> u64 {
    bridge_calls().get()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bridge_counter_is_monotone_under_adds() {
        let before = bridge_calls_total();
        add_bridge_calls(0); // no-op delta
        assert_eq!(bridge_calls_total(), before);
        add_bridge_calls(3);
        add_bridge_calls(5);
        // Other tests drop trees concurrently, so assert a lower bound,
        // not equality.
        assert!(bridge_calls_total() >= before + 8);
    }

    #[test]
    fn shim_and_registry_agree() {
        add_bridge_calls(2);
        assert_eq!(
            bridge_calls_total(),
            crate::obs::counter("brownian.bridge_calls").get()
        );
    }
}
