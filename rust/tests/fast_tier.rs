//! The fast kernel tier's contract, end to end.
//!
//! Two pins:
//!
//! 1. **Exact is untouched.** `KernelTier::Exact` (the default) stays
//!    bit-identical to the per-path scalar engine — the same oracle the
//!    pre-tier engine was pinned to — for solves and gradients. Adding
//!    the tier machinery must not move a single exact-tier bit.
//! 2. **Fast is close.** `KernelTier::Fast` (fused drift+diffusion,
//!    blocked reassociation-free-per-row reductions in the nn kernels)
//!    agrees with the exact tier to tight relative tolerance on solves,
//!    stochastic-adjoint gradients, and batched ELBO training steps —
//!    across schemes (Euler–Maruyama / Heun / Milstein) and batch
//!    layouts that cross the engine's internal chunk boundary
//!    (CHUNK = 32: sizes 1, 5, 32, 33, 48).

use sdegrad::adjoint::AdjointConfig;
use sdegrad::api::{
    sensitivity_batch, sensitivity_batch_per_path, solve_batch,
    solve_batch_per_path, SdeProblem, SensAlg, SolveOptions, StepControl,
};
use sdegrad::latent::{elbo_step_batch, ElboConfig, LatentSdeConfig, LatentSdeModel};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;
use sdegrad::sde::ou::OrnsteinUhlenbeck;
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::{KernelTier, ReplicatedSde};
use sdegrad::solvers::Method;

/// Batch sizes that exercise the SoA engine's chunk layouts: scalar-like
/// (1), partial chunk (5), exactly one chunk (32), chunk + remainder
/// (33), and one-and-a-half chunks (48).
const BATCH_SIZES: [usize; 5] = [1, 5, 32, 33, 48];

/// Fast-vs-exact relative budget for forward solves (a few hundred
/// steps of within-row reassociation: O(ulp) per step).
const SOLVE_RTOL: f64 = 1e-9;
/// Budget for gradients and ELBO steps — the adjoint sweep squares the
/// number of reassociated reductions per output.
const GRAD_RTOL: f64 = 1e-7;

fn assert_close(a: &[f64], b: &[f64], rtol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= rtol * scale,
            "{what}[{i}]: exact {x} vs fast {y} (rtol {rtol})"
        );
    }
}

/// Fast solves agree with exact to tolerance on the multiplicative-noise
/// GBM fleet, per scheme × batch layout.
#[test]
fn fast_solve_matches_exact_on_gbm_across_methods_and_batch_sizes() {
    let dim = 10;
    let gbm = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(21), dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    for method in [Method::EulerMaruyama, Method::Heun, Method::MilsteinIto] {
        for bsz in BATCH_SIZES {
            let replicates = prob.replicates(PrngKey::from_seed(1000 + bsz as u64), bsz);
            let exact = solve_batch(&replicates, &SolveOptions::fixed(method, 120));
            let fast = solve_batch(
                &replicates,
                &SolveOptions::fixed(method, 120).tier(KernelTier::Fast),
            );
            for (a, b) in exact.iter().zip(&fast) {
                assert_close(
                    &a.states,
                    &b.states,
                    SOLVE_RTOL,
                    &format!("gbm {method:?} b={bsz}"),
                );
            }
        }
    }
}

/// Same pin on the additive-noise OU system (its fast overrides take the
/// flat-elementwise path rather than the fused GBM kernels).
#[test]
fn fast_solve_matches_exact_on_ou() {
    let ou = OrnsteinUhlenbeck::new(3);
    let theta = [1.2, 0.3, 0.5];
    let x0 = [0.9, 0.4, -0.2];
    let prob = SdeProblem::new(&ou, &x0, (0.0, 1.0)).params(&theta);
    for method in [Method::EulerMaruyama, Method::Heun, Method::MilsteinIto] {
        for bsz in BATCH_SIZES {
            let replicates = prob.replicates(PrngKey::from_seed(2000 + bsz as u64), bsz);
            let exact = solve_batch(&replicates, &SolveOptions::fixed(method, 120));
            let fast = solve_batch(
                &replicates,
                &SolveOptions::fixed(method, 120).tier(KernelTier::Fast),
            );
            for (a, b) in exact.iter().zip(&fast) {
                assert_close(
                    &a.states,
                    &b.states,
                    SOLVE_RTOL,
                    &format!("ou {method:?} b={bsz}"),
                );
            }
        }
    }
}

/// Fast stochastic-adjoint gradients agree with exact to tolerance,
/// including on a chunk-crossing batch.
#[test]
fn fast_gradients_match_exact_across_methods() {
    let dim = 10;
    let gbm = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(22), dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    let step = StepControl::Steps(100);
    for method in [Method::EulerMaruyama, Method::Heun, Method::MilsteinIto] {
        let alg = SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: method,
            ..Default::default()
        });
        for bsz in [5usize, 33] {
            let replicates = prob.replicates(PrngKey::from_seed(3000 + bsz as u64), bsz);
            let exact = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
            let fast = sensitivity_batch(
                &replicates,
                &alg,
                step,
                ExecConfig::new().tier(KernelTier::Fast),
            );
            for (a, b) in exact.iter().zip(&fast) {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                assert_close(
                    &a.dtheta,
                    &b.dtheta,
                    GRAD_RTOL,
                    &format!("grad {method:?} b={bsz}"),
                );
                assert_close(&a.dz0, &b.dz0, GRAD_RTOL, &format!("dz0 {method:?} b={bsz}"));
            }
        }
    }
}

fn tiny_latent_model() -> (LatentSdeModel, Vec<f64>) {
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(40));
    (model, params)
}

/// A full batched ELBO training step (encoder → posterior solve →
/// decoder → adjoint → flat gradient) agrees across tiers to tolerance —
/// the gate that makes `train --tier fast` a usable estimator.
#[test]
fn fast_elbo_step_matches_exact_within_tolerance() {
    let (model, params) = tiny_latent_model();
    let times: Vec<f64> = (0..6).map(|k| 0.1 * k as f64).collect();
    let n_seq = 3;
    let mut obs = vec![0.0; n_seq * times.len() * 2];
    PrngKey::from_seed(41).fill_normal(0, &mut obs);
    let rows: Vec<&[f64]> = obs.chunks(times.len() * 2).collect();
    let keys: Vec<PrngKey> = (0..n_seq).map(|m| PrngKey::from_seed(50 + m as u64)).collect();

    let exact_cfg = ElboConfig { substeps: 3, kl_weight: 0.7, exec: ExecConfig::default() };
    let fast_cfg =
        ElboConfig { substeps: 3, kl_weight: 0.7, exec: ExecConfig::new().tier(KernelTier::Fast) };
    let exact = elbo_step_batch(&model, &params, &times, &rows, &keys, &exact_cfg, 2, 1);
    let fast = elbo_step_batch(&model, &params, &times, &rows, &keys, &fast_cfg, 2, 1);

    assert_close(&[exact.loss], &[fast.loss], GRAD_RTOL, "elbo loss");
    assert_close(&exact.per_path_loss, &fast.per_path_loss, GRAD_RTOL, "per-path loss");
    assert_close(&exact.grad, &fast.grad, GRAD_RTOL, "elbo gradient");
}

/// THE exact-tier regression pin: with the tier machinery in place,
/// `KernelTier::Exact` remains bit-identical to the per-path scalar
/// engine — the same float stream as before the tier existed.
#[test]
fn exact_tier_stays_bit_identical_to_per_path_engine() {
    let dim = 10;
    let gbm = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(23), dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    let replicates = prob.replicates(PrngKey::from_seed(4000), 33);

    // An explicit Exact tier and the default options are the same thing.
    let opts = SolveOptions::fixed(Method::MilsteinIto, 100);
    let opts_exact = SolveOptions::fixed(Method::MilsteinIto, 100).tier(KernelTier::Exact);
    let batched = solve_batch(&replicates, &opts_exact);
    let default_tier = solve_batch(&replicates, &opts);
    let per_path = solve_batch_per_path(&replicates, &opts);
    for ((a, b), c) in batched.iter().zip(&default_tier).zip(&per_path) {
        assert_eq!(a.states, b.states, "explicit Exact differs from default options");
        assert_eq!(a.states, c.states, "Exact tier diverged from the per-path engine");
    }

    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let step = StepControl::Steps(100);
    let g_exact =
        sensitivity_batch(&replicates, &alg, step, ExecConfig::new().tier(KernelTier::Exact));
    let g_default = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
    let g_per_path = sensitivity_batch_per_path(&replicates, &alg, step);
    for ((a, b), c) in g_exact.iter().zip(&g_default).zip(&g_per_path) {
        let (a, b, c) = (a.as_ref().unwrap(), b.as_ref().unwrap(), c.as_ref().unwrap());
        assert_eq!(a.dtheta, b.dtheta, "explicit Exact grad differs from default");
        assert_eq!(a.dtheta, c.dtheta, "Exact grad diverged from the per-path engine");
        assert_eq!(a.dz0, c.dz0, "Exact dz0 diverged from the per-path engine");
    }
}

/// Fast must actually differ somewhere (otherwise the tier is wired to
/// nothing and the tolerance suite proves nothing). One reassociated
/// blocked reduction over a 64-wide hidden layer is enough to move the
/// last bits on some output.
#[test]
fn fast_tier_is_actually_wired_in() {
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 64,
        diff_hidden: 16,
        enc_hidden: 32,
        obs_noise_std: 0.1,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(42));
    let times: Vec<f64> = (0..6).map(|k| 0.1 * k as f64).collect();
    let mut obs = vec![0.0; times.len() * 2];
    PrngKey::from_seed(43).fill_normal(0, &mut obs);
    let rows: Vec<&[f64]> = vec![obs.as_slice()];
    let keys = [PrngKey::from_seed(44)];

    let exact_cfg = ElboConfig { substeps: 3, kl_weight: 0.7, exec: ExecConfig::default() };
    let fast_cfg =
        ElboConfig { substeps: 3, kl_weight: 0.7, exec: ExecConfig::new().tier(KernelTier::Fast) };
    let exact = elbo_step_batch(&model, &params, &times, &rows, &keys, &exact_cfg, 2, 1);
    let fast = elbo_step_batch(&model, &params, &times, &rows, &keys, &fast_cfg, 2, 1);
    let any_bit_moved = exact.loss.to_bits() != fast.loss.to_bits()
        || exact
            .grad
            .iter()
            .zip(&fast.grad)
            .any(|(a, b)| a.to_bits() != b.to_bits());
    assert!(any_bit_moved, "fast tier produced the exact tier's bit stream everywhere");
}
