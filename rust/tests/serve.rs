//! End-to-end tests of the `sdegrad serve` subsystem over real
//! localhost sockets.
//!
//! The acceptance pin: for fixed request seeds, every `/v1/*` response
//! is **byte-identical** to the per-request scalar engine call
//! ([`sdegrad::serve::batcher::scalar_response`]) regardless of
//! concurrent-client count, micro-batch layout (`max_batch` 1 vs 16,
//! workers 1 vs 8), **shard count (1/2/4)**, arrival order, queue
//! state, cache state, and response framing (chunked streaming vs
//! `Content-Length`) — the serving payoff of the engine's
//! bit-identical-batching guarantee. Plus the error table: malformed
//! JSON, unknown endpoint/model, oversized body, wrong method, shape
//! mismatches, and admission-control shedding (429 `overloaded` with
//! `Retry-After`) all answer with stable JSON error codes; under
//! overload every request either gets oracle bytes or a well-formed
//! 429 — never a reset connection. `GET /metrics` answers strict JSON
//! with monotone, shard-count-independent request totals.

use std::net::SocketAddr;

use sdegrad::latent::{LatentSdeConfig, LatentSdeModel};
use sdegrad::metrics::json::parse_json;
use sdegrad::prng::PrngKey;
use sdegrad::sde::KernelTier;
use sdegrad::serve::batcher::scalar_response;
use sdegrad::serve::{client, protocol, ModelRegistry, ServeConfig, Server};

fn tiny_cfg() -> LatentSdeConfig {
    LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    }
}

/// Two named models (different init seeds ⇒ different fingerprints).
fn registry() -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    let alpha = LatentSdeModel::new(tiny_cfg());
    let p_alpha = alpha.init_params(PrngKey::from_seed(1));
    reg.insert("alpha", alpha, p_alpha).unwrap();
    let beta = LatentSdeModel::new(tiny_cfg());
    let p_beta = beta.init_params(PrngKey::from_seed(2));
    reg.insert("beta", beta, p_beta).unwrap();
    reg
}

fn times_json() -> String {
    "[0,0.1,0.2,0.3,0.4]".to_string()
}

fn obs_json(seed: u64) -> String {
    let mut obs = vec![0.0; 5 * 2];
    PrngKey::from_seed(seed).fill_normal(0, &mut obs);
    let rows: Vec<String> =
        obs.chunks_exact(2).map(|r| format!("[{},{}]", r[0], r[1])).collect();
    format!("[{}]", rows.join(","))
}

/// One HTTP request over a fresh connection via the shared serving
/// client ([`sdegrad::serve::client`]); returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let (status, body) = client::request(addr, method, path, body).expect("http request");
    assert_ne!(status, 0, "unparseable response head");
    (status, body)
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, Vec<u8>) {
    http(addr, "POST", path, body)
}

/// The request mix used by the invariance tests: all three endpoints,
/// both models, distinct seeds. Returns (path, body) pairs.
fn request_mix() -> Vec<(String, String)> {
    let mut reqs = Vec::new();
    for (i, model) in ["alpha", "beta", "alpha", "alpha"].iter().enumerate() {
        reqs.push((
            "/v1/simulate".to_string(),
            format!(
                "{{\"model\": \"{model}\", \"seed\": {}, \"times\": {}, \"substeps\": 3}}",
                10 + i,
                times_json()
            ),
        ));
        reqs.push((
            "/v1/reconstruct".to_string(),
            format!(
                "{{\"model\": \"{model}\", \"seed\": {}, \"times\": {}, \"obs\": {}, \
                 \"substeps\": 3}}",
                20 + i,
                times_json(),
                obs_json(300 + i as u64)
            ),
        ));
        reqs.push((
            "/v1/elbo".to_string(),
            format!(
                "{{\"model\": \"{model}\", \"seed\": {}, \"times\": {}, \"obs\": {}, \
                 \"substeps\": 3, \"samples\": 2, \"kl_weight\": 0.4}}",
                30 + i,
                times_json(),
                obs_json(400 + i as u64)
            ),
        ));
    }
    reqs
}

/// Per-request scalar oracle bytes, computed without any server.
fn expected_bytes(reqs: &[(String, String)]) -> Vec<Vec<u8>> {
    let reg = registry();
    reqs.iter()
        .map(|(path, body)| {
            let req = protocol::parse_request(path, body).expect("oracle parse");
            let entry = reg.get(req.model()).expect("oracle model");
            scalar_response(entry, &req, KernelTier::Exact).expect("oracle response")
        })
        .collect()
}

/// THE acceptance pin: responses are byte-identical to the scalar
/// oracle across micro-batch layouts, worker counts, concurrent-client
/// arrival orders, and repetition (cache hits).
#[test]
fn responses_invariant_across_batch_layouts_concurrency_and_cache() {
    let reqs = request_mix();
    let expected = expected_bytes(&reqs);

    for (max_batch, workers, n_clients) in [(1usize, 1usize, 2usize), (16, 8, 6)] {
        let server = Server::start(
            registry(),
            ServeConfig {
                port: 0,
                workers,
                max_batch,
                // Generous window so concurrent requests really coalesce
                // into shared engine calls on the 16-batch config.
                max_wait_us: 2000,
                cache_capacity: 64,
                ..Default::default()
            },
        )
        .expect("server start");
        let addr = server.addr();

        // Concurrent clients, interleaved request ownership (client c
        // takes requests c, c+n_clients, …) so arrival order is
        // scrambled relative to the request list.
        let results: Vec<Vec<(usize, Vec<u8>)>> = std::thread::scope(|scope| {
            let reqs = &reqs;
            let handles: Vec<_> = (0..n_clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = c;
                        while i < reqs.len() {
                            let (path, body) = &reqs[i];
                            let (status, bytes) = post(addr, path, body);
                            assert_eq!(status, 200, "request {i} failed: {bytes:?}");
                            out.push((i, bytes));
                            i += n_clients;
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
        });
        for (i, bytes) in results.into_iter().flatten() {
            assert_eq!(
                bytes, expected[i],
                "request {i} diverged from the scalar oracle \
                 (max_batch={max_batch}, workers={workers})"
            );
        }

        // Second pass, sequential: every request now hits the cache and
        // must STILL byte-equal the oracle (hit == miss pin).
        for (i, (path, body)) in reqs.iter().enumerate() {
            let (status, bytes) = post(addr, path, body);
            assert_eq!(status, 200);
            assert_eq!(bytes, expected[i], "cache hit diverged on request {i}");
        }
        server.shutdown();
    }
}

/// Cache disabled vs enabled must not change a byte (the cache is an
/// optimization, never an answer source of its own).
#[test]
fn cache_disabled_and_enabled_serve_identical_bytes() {
    let (path, body) = (
        "/v1/elbo",
        format!(
            "{{\"model\": \"alpha\", \"seed\": 5, \"times\": {}, \"obs\": {}, \
             \"substeps\": 2, \"samples\": 2}}",
            times_json(),
            obs_json(55)
        ),
    );
    let mut bodies = Vec::new();
    for cache_capacity in [0usize, 128] {
        let server = Server::start(
            registry(),
            ServeConfig { port: 0, workers: 2, cache_capacity, ..Default::default() },
        )
        .unwrap();
        // Twice per server: fresh compute, then (with cache) a hit.
        let (s1, b1) = post(server.addr(), path, &body);
        let (s2, b2) = post(server.addr(), path, &body);
        assert_eq!((s1, s2), (200, 200));
        assert_eq!(b1, b2);
        bodies.push(b1);
        server.shutdown();
    }
    assert_eq!(bodies[0], bodies[1], "cache on/off changed response bytes");
}

#[test]
fn healthz_lists_models_with_fingerprints() {
    let server = Server::start(registry(), ServeConfig { port: 0, ..Default::default() })
        .unwrap();
    let (status, body) = http(server.addr(), "GET", "/healthz", "");
    assert_eq!(status, 200);
    let v = parse_json(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    let models = v.get("models").unwrap().as_array().unwrap();
    assert_eq!(models.len(), 2);
    let names: Vec<&str> =
        models.iter().map(|m| m.get("name").unwrap().as_str().unwrap()).collect();
    assert!(names.contains(&"alpha") && names.contains(&"beta"));
    let fps: Vec<&str> = models
        .iter()
        .map(|m| m.get("fingerprint").unwrap().as_str().unwrap())
        .collect();
    assert_ne!(fps[0], fps[1], "distinct checkpoints must have distinct fingerprints");
    server.shutdown();
}

/// ELBO responses carry the exact floats of the direct engine call
/// (shortest-roundtrip formatting both ways).
#[test]
fn elbo_response_floats_roundtrip_to_the_engine_values() {
    use sdegrad::latent::{elbo_value_multi, ElboConfig};
    let server = Server::start(registry(), ServeConfig { port: 0, ..Default::default() })
        .unwrap();
    let body = format!(
        "{{\"model\": \"beta\", \"seed\": 9, \"times\": {}, \"obs\": {}, \
         \"substeps\": 3, \"samples\": 3, \"kl_weight\": 0.25}}",
        times_json(),
        obs_json(77)
    );
    let (status, bytes) = post(server.addr(), "/v1/elbo", &body);
    assert_eq!(status, 200);
    server.shutdown();

    let model = LatentSdeModel::new(tiny_cfg());
    let params = model.init_params(PrngKey::from_seed(2)); // "beta"
    let req = protocol::parse_request("/v1/elbo", &body).unwrap();
    let sdegrad::serve::ServeRequest::Elbo(r) = &req else { panic!("wrong variant") };
    let out = elbo_value_multi(
        &model,
        &params,
        &r.times,
        &r.obs,
        PrngKey::from_seed(9),
        &ElboConfig { substeps: 3, kl_weight: 0.25, ..ElboConfig::default() },
        3,
    );
    let v = parse_json(std::str::from_utf8(&bytes).unwrap()).unwrap();
    assert_eq!(v.get("loss").unwrap().as_f64().unwrap().to_bits(), out.loss.to_bits());
    assert_eq!(v.get("kl_z0").unwrap().as_f64().unwrap().to_bits(), out.kl_z0.to_bits());
    let per = v.get("per_sample_loss").unwrap().as_array().unwrap();
    assert_eq!(per.len(), 3);
    for (got, want) in per.iter().zip(&out.per_sample_loss) {
        assert_eq!(got.as_f64().unwrap().to_bits(), want.to_bits());
    }
}

/// The error table: every failure mode answers with the documented
/// status + stable JSON error code. (The 429 `overloaded` row needs a
/// server under load — pinned in
/// [`overload_sheds_well_formed_429s_and_never_corrupts_successes`].)
#[test]
fn error_responses_have_stable_codes() {
    let server = Server::start(
        registry(),
        ServeConfig { port: 0, workers: 2, max_body_bytes: 4096, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();
    let code_of = |body: &[u8]| -> String {
        parse_json(std::str::from_utf8(body).unwrap())
            .unwrap()
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(|c| c.as_str())
            .unwrap_or("<none>")
            .to_string()
    };

    // Malformed JSON.
    let (status, body) = post(addr, "/v1/simulate", "this is not json");
    assert_eq!((status, code_of(&body).as_str()), (400, "bad_json"));

    // Unknown endpoint.
    let (status, body) = post(addr, "/v1/nope", "{}");
    assert_eq!((status, code_of(&body).as_str()), (404, "unknown_endpoint"));

    // Unknown model.
    let (status, body) = post(
        addr,
        "/v1/simulate",
        &format!("{{\"model\": \"gamma\", \"seed\": 1, \"times\": {}}}", times_json()),
    );
    assert_eq!((status, code_of(&body).as_str()), (404, "unknown_model"));

    // Oversized body (the server caps at 4096 above).
    let big = format!(
        "{{\"seed\": 1, \"times\": {}, \"pad\": \"{}\"}}",
        times_json(),
        "x".repeat(8192)
    );
    let (status, body) = post(addr, "/v1/simulate", &big);
    assert_eq!((status, code_of(&body).as_str()), (413, "body_too_large"));

    // Wrong method on an API endpoint and on healthz.
    let (status, body) = http(addr, "GET", "/v1/simulate", "");
    assert_eq!((status, code_of(&body).as_str()), (405, "method_not_allowed"));
    let (status, _) = post(addr, "/healthz", "{}");
    assert_eq!(status, 405);

    // Obs shape mismatch against the model (3-wide rows, 2-dim model).
    let (status, body) = post(
        addr,
        "/v1/reconstruct",
        r#"{"model": "alpha", "seed": 1, "times": [0, 0.1],
            "obs": [[1, 2, 3], [4, 5, 6]]}"#,
    );
    assert_eq!((status, code_of(&body).as_str()), (400, "bad_request"));

    // Missing seed.
    let (status, body) =
        post(addr, "/v1/simulate", &format!("{{\"times\": {}}}", times_json()));
    assert_eq!((status, code_of(&body).as_str()), (400, "bad_request"));

    // Non-JSON number literals: the strict JSON number grammar rejects
    // `inf` and a leading `+` — a 400, never a silently-coerced float.
    let (status, body) = post(
        addr,
        "/v1/simulate",
        "{\"model\": \"alpha\", \"seed\": 1, \"times\": [0, inf], \"substeps\": 2}",
    );
    assert_eq!((status, code_of(&body).as_str()), (400, "bad_json"));
    let (status, body) = post(
        addr,
        "/v1/simulate",
        "{\"model\": \"alpha\", \"seed\": 1, \"times\": [0, +0.1], \"substeps\": 2}",
    );
    assert_eq!((status, code_of(&body).as_str()), (400, "bad_json"));

    server.shutdown();
}

/// A server started on the fast kernel tier still upholds the
/// batched-equals-scalar byte contract — against the fast-tier oracle.
#[test]
fn fast_tier_server_matches_fast_tier_oracle_bytes() {
    let body = format!(
        "{{\"model\": \"alpha\", \"seed\": 31, \"times\": {}, \"obs\": {}, \
         \"substeps\": 3, \"samples\": 2, \"kl_weight\": 0.4}}",
        times_json(),
        obs_json(470)
    );
    let expected = {
        let reg = registry();
        let req = protocol::parse_request("/v1/elbo", &body).unwrap();
        let entry = reg.get("alpha").unwrap();
        scalar_response(entry, &req, KernelTier::Fast).unwrap()
    };
    let server = Server::start(
        registry(),
        ServeConfig { port: 0, workers: 2, ..Default::default() }.tier(KernelTier::Fast),
    )
    .unwrap();
    let (status, bytes) = post(server.addr(), "/v1/elbo", &body);
    assert_eq!(status, 200);
    assert_eq!(bytes, expected, "fast-tier served bytes diverged from the fast oracle");
    server.shutdown();
}

/// The tentpole pin: shard count is invisible in success bytes. The
/// same concurrent request mix against 1-, 2-, and 4-shard servers
/// answers byte-identically to the scalar oracle on every request.
#[test]
fn responses_invariant_across_shard_counts() {
    let reqs = request_mix();
    let expected = expected_bytes(&reqs);
    for shards in [1usize, 2, 4] {
        let server = Server::start(
            registry(),
            ServeConfig {
                port: 0,
                workers: 4,
                max_batch: 8,
                max_wait_us: 2000,
                shards,
                cache_capacity: 0,
                ..Default::default()
            },
        )
        .expect("server start");
        let addr = server.addr();
        let results: Vec<Vec<(usize, Vec<u8>)>> = std::thread::scope(|scope| {
            let reqs = &reqs;
            let handles: Vec<_> = (0..3usize)
                .map(|c| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = c;
                        while i < reqs.len() {
                            let (path, body) = &reqs[i];
                            let (status, bytes) = post(addr, path, body);
                            assert_eq!(status, 200, "request {i} failed: {bytes:?}");
                            out.push((i, bytes));
                            i += 3;
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
        });
        for (i, bytes) in results.into_iter().flatten() {
            assert_eq!(
                bytes, expected[i],
                "request {i} diverged from the scalar oracle (shards={shards})"
            );
        }
        server.shutdown();
    }
}

/// A deliberately slow ELBO request (long grid × many samples) that
/// keeps a dispatcher busy for an observable interval.
fn slow_elbo_body(seed: u64) -> String {
    let n = 96;
    let times: Vec<String> = (0..n).map(|j| format!("{}", 0.02 * j as f64)).collect();
    let mut obs = vec![0.0; n * 2];
    PrngKey::from_seed(7000 + seed).fill_normal(0, &mut obs);
    let rows: Vec<String> =
        obs.chunks_exact(2).map(|r| format!("[{},{}]", r[0], r[1])).collect();
    format!(
        "{{\"model\": \"alpha\", \"seed\": {seed}, \"times\": [{}], \"obs\": [{}], \
         \"substeps\": 3, \"samples\": 6, \"kl_weight\": 0.4}}",
        times.join(","),
        rows.join(",")
    )
}

/// Sum a per-shard integer field out of a parsed `/metrics` document.
fn metrics_total(v: &sdegrad::metrics::json::JsonValue, field: &str) -> u64 {
    v.get("shards")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|sh| sh.get(field).unwrap().as_u64().unwrap())
        .sum()
}

fn scrape_metrics(addr: SocketAddr) -> sdegrad::metrics::json::JsonValue {
    let (status, body) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    // parse_json is the crate's STRICT grammar — this line is the
    // "valid strict JSON" assertion.
    parse_json(std::str::from_utf8(&body).expect("metrics is UTF-8")).expect("strict JSON")
}

/// The overload contract over real sockets: a queue past its cell
/// budget sheds with a well-formed 429 (`Retry-After` header, stable
/// `overloaded` JSON code), every admitted request still answers oracle
/// bytes, and no connection is ever reset. The shed itself is forced
/// deterministically: with a 1-cell budget, ANY submit that finds the
/// shard queue non-empty must shed, so we park one slow request in the
/// dispatcher, one in the queue (observed via `/metrics` depth), then
/// probe.
#[test]
fn overload_sheds_well_formed_429s_and_never_corrupts_successes() {
    let server = Server::start(
        registry(),
        ServeConfig {
            port: 0,
            workers: 4,
            // max_batch 1: the dispatcher takes exactly one job at a
            // time, so a parked second request stays visibly queued.
            max_batch: 1,
            max_wait_us: 0,
            shards: 1,
            queue_cells: 1,
            cache_capacity: 0,
            ..Default::default()
        },
    )
    .expect("server start");
    let addr = server.addr();

    let oracle = |body: &str| {
        let reg = registry();
        let req = protocol::parse_request("/v1/elbo", body).expect("oracle parse");
        scalar_response(reg.get("alpha").unwrap(), &req, KernelTier::Exact).unwrap()
    };

    // Bounded wait on an observable /metrics condition; false = the
    // window closed (that attempt retries) rather than a hung test.
    let wait_for = |pred: &dyn Fn() -> bool| -> bool {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while std::time::Instant::now() < deadline {
            if pred() {
                return true;
            }
            std::thread::yield_now();
        }
        false
    };

    let mut shed_seen = 0usize;
    for attempt in 0..3u64 {
        let a = slow_elbo_body(10 + attempt);
        let b = slow_elbo_body(20 + attempt);
        let probe = slow_elbo_body(30 + attempt);
        let (expected_a, expected_b) = (oracle(&a), oracle(&b));
        let base = metrics_total(&scrape_metrics(addr), "submitted");
        let got_429 = std::thread::scope(|scope| {
            let h_a = scope.spawn(|| {
                client::request_with_headers(addr, "POST", "/v1/elbo", &a)
                    .expect("connection reset on request A")
            });
            // A admitted (empty queue) and popped by the dispatcher; only
            // then send B, so B meets an empty queue and is admitted too.
            wait_for(&|| metrics_total(&scrape_metrics(addr), "submitted") > base);
            wait_for(&|| metrics_total(&scrape_metrics(addr), "depth") == 0);
            let h_b = scope.spawn(|| {
                client::request_with_headers(addr, "POST", "/v1/elbo", &b)
                    .expect("connection reset on request B")
            });
            // B queued behind the in-flight A: depth 1. Probe while the
            // queue is provably non-empty — over a 1-cell budget, the
            // probe must shed unless A finished in the meantime.
            wait_for(&|| metrics_total(&scrape_metrics(addr), "depth") >= 1);
            let (status, head, bytes) =
                client::request_with_headers(addr, "POST", "/v1/elbo", &probe)
                    .expect("connection reset on probe");
            let got_429 = if status == 429 {
                assert!(
                    head.contains("Retry-After:"),
                    "429 must carry Retry-After:\n{head}"
                );
                let v = parse_json(std::str::from_utf8(&bytes).unwrap())
                    .expect("429 body is strict JSON");
                let code = v.get("error").unwrap().get("code").unwrap();
                assert_eq!(code.as_str(), Some("overloaded"));
                true
            } else {
                // The race window closed (A finished first): the probe
                // was admitted and must then be byte-perfect.
                assert_eq!(status, 200, "unexpected status {status}: {bytes:?}");
                assert_eq!(bytes, oracle(&probe), "admitted probe diverged");
                false
            };
            // Shedding never touches admitted requests' bytes.
            let (st_a, _, by_a) = h_a.join().expect("client A panicked");
            let (st_b, _, by_b) = h_b.join().expect("client B panicked");
            assert_eq!((st_a, st_b), (200, 200));
            assert_eq!(by_a, expected_a, "request A bytes corrupted by overload");
            assert_eq!(by_b, expected_b, "request B bytes corrupted by overload");
            got_429
        });
        if got_429 {
            shed_seen += 1;
            break;
        }
    }
    assert!(shed_seen > 0, "never observed a 429 in 3 attempts");
    // The shed is visible in /metrics.
    let v = scrape_metrics(addr);
    assert!(metrics_total(&v, "shed") >= 1);
    server.shutdown();
}

/// `GET /metrics` answers strict JSON with the documented shape, and
/// its counters are monotone across scrapes.
#[test]
fn metrics_endpoint_is_strict_json_with_monotone_counters() {
    let server = Server::start(
        registry(),
        ServeConfig { port: 0, workers: 2, shards: 2, cache_capacity: 8, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();

    let v0 = scrape_metrics(addr);
    let shards = v0.get("shards").unwrap().as_array().unwrap();
    assert_eq!(shards.len(), 2);
    for (i, sh) in shards.iter().enumerate() {
        assert_eq!(sh.get("shard").unwrap().as_usize().unwrap(), i);
        for field in ["depth", "queued_cells", "submitted", "shed", "batches", "jobs"] {
            assert!(sh.get(field).is_some(), "missing per-shard field {field}");
        }
        assert_eq!(sh.get("occupancy").unwrap().as_array().unwrap().len(), 6);
    }
    // Bucket labels: finite upper bounds then the open-ended null.
    let le = v0.get("occupancy_le").unwrap().as_array().unwrap();
    assert_eq!(le.len(), 6);
    assert_eq!(le[0].as_u64(), Some(1));
    assert_eq!(le[5], sdegrad::metrics::json::JsonValue::Null);
    for section in ["totals", "cache", "engine"] {
        assert!(v0.get(section).is_some(), "missing section {section}");
    }
    let engine = v0.get("engine").unwrap();
    assert!(engine.get("pool_workers").unwrap().as_u64().unwrap() >= 1);

    // Traffic, then a second scrape: request totals grow by exactly the
    // request count, and every counter is monotone.
    let reqs = request_mix();
    for (path, body) in &reqs {
        let (status, _) = post(addr, path, body);
        assert_eq!(status, 200);
    }
    let v1 = scrape_metrics(addr);
    for field in ["submitted", "shed", "batches", "jobs"] {
        let (t0, t1) = (metrics_total(&v0, field), metrics_total(&v1, field));
        assert!(t1 >= t0, "counter {field} went backwards: {t0} -> {t1}");
        let j0 = v0.get("totals").unwrap().get(field).unwrap().as_u64().unwrap();
        assert_eq!(j0, t0, "totals.{field} disagrees with the per-shard sum");
        let j1 = v1.get("totals").unwrap().get(field).unwrap().as_u64().unwrap();
        assert_eq!(j1, t1, "totals.{field} disagrees with the per-shard sum");
    }
    assert_eq!(
        metrics_total(&v1, "submitted") - metrics_total(&v0, "submitted"),
        reqs.len() as u64
    );
    assert_eq!(metrics_total(&v1, "jobs") - metrics_total(&v0, "jobs"), reqs.len() as u64);
    assert_eq!(metrics_total(&v1, "shed"), 0);
    server.shutdown();
}

/// The same traffic produces the same `submitted`/`jobs`/`shed` totals
/// whatever the shard count — sharding redistributes work, it never
/// invents or loses requests.
#[test]
fn metrics_request_totals_are_shard_count_independent() {
    let reqs = request_mix();
    let mut seen = Vec::new();
    for shards in [1usize, 2, 4] {
        let server = Server::start(
            registry(),
            ServeConfig { port: 0, workers: 3, shards, cache_capacity: 0, ..Default::default() },
        )
        .unwrap();
        let addr = server.addr();
        for (path, body) in &reqs {
            let (status, _) = post(addr, path, body);
            assert_eq!(status, 200);
        }
        let v = scrape_metrics(addr);
        seen.push((
            metrics_total(&v, "submitted"),
            metrics_total(&v, "jobs"),
            metrics_total(&v, "shed"),
        ));
        server.shutdown();
    }
    assert_eq!(seen[0], (reqs.len() as u64, reqs.len() as u64, 0));
    assert!(seen.iter().all(|t| *t == seen[0]), "totals varied with shard count: {seen:?}");
}

/// Streaming is transport, not content: a `/v1/simulate` response over
/// the chunked threshold arrives `Transfer-Encoding: chunked` and
/// decodes to exactly the bytes a non-streaming server sends; short
/// responses and non-simulate endpoints keep `Content-Length` framing.
#[test]
fn chunked_streaming_preserves_bytes_and_only_triggers_past_threshold() {
    let body = format!(
        "{{\"model\": \"alpha\", \"seed\": 3, \"times\": [{}], \"substeps\": 2}}",
        (0..48).map(|j| format!("{}", 0.05 * j as f64)).collect::<Vec<_>>().join(",")
    );
    let elbo = format!(
        "{{\"model\": \"alpha\", \"seed\": 4, \"times\": {}, \"obs\": {}, \
         \"substeps\": 2, \"samples\": 2}}",
        times_json(),
        obs_json(90)
    );

    let start = |stream_threshold_bytes: usize| {
        Server::start(
            registry(),
            ServeConfig {
                port: 0,
                workers: 2,
                stream_threshold_bytes,
                cache_capacity: 0,
                ..Default::default()
            },
        )
        .unwrap()
    };

    // Streaming server: every simulate 200 streams (threshold 1).
    let streaming = start(1);
    let (status, head, streamed) =
        client::request_with_headers(streaming.addr(), "POST", "/v1/simulate", &body).unwrap();
    assert_eq!(status, 200);
    let lower = head.to_ascii_lowercase();
    assert!(lower.contains("transfer-encoding: chunked"), "not chunked:\n{head}");
    assert!(!lower.contains("content-length"), "chunked reply must not set Content-Length");
    // Non-simulate endpoints never stream.
    let (status, ehead, _) =
        client::request_with_headers(streaming.addr(), "POST", "/v1/elbo", &elbo).unwrap();
    assert_eq!(status, 200);
    assert!(!ehead.to_ascii_lowercase().contains("transfer-encoding"));
    streaming.shutdown();

    // Plain server (streaming disabled): same request, Content-Length
    // framing, and — the point — identical payload bytes.
    let plain = start(usize::MAX);
    let (status, phead, unstreamed) =
        client::request_with_headers(plain.addr(), "POST", "/v1/simulate", &body).unwrap();
    assert_eq!(status, 200);
    assert!(phead.to_ascii_lowercase().contains("content-length"));
    plain.shutdown();

    assert_eq!(streamed, unstreamed, "chunked framing changed payload bytes");
    let reg = registry();
    let req = protocol::parse_request("/v1/simulate", &body).unwrap();
    let expected = scalar_response(reg.get("alpha").unwrap(), &req, KernelTier::Exact).unwrap();
    assert_eq!(streamed, expected, "streamed bytes diverged from the scalar oracle");
}
