//! Cross-module integration tests + randomized property tests (via the
//! in-repo mini-proptest harness — DESIGN.md §3).

use sdegrad::adjoint::{AdjointConfig, NoiseMode};
use sdegrad::api::{SdeProblem, SensAlg, StepControl};
use sdegrad::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
use sdegrad::coordinator::config::TrainConfig;
use sdegrad::coordinator::{load_params, save_params, train_latent_sde};
use sdegrad::data::gbm::{generate as gbm_generate, GbmConfig};
use sdegrad::latent::{elbo_step, ElboConfig, LatentSdeConfig, LatentSdeModel};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;
use sdegrad::sde::problems::{sample_experiment_setup, Example1, Example2, Example3};
use sdegrad::sde::{ReplicatedSde, ScalarSde};
use sdegrad::solvers::Method;
use sdegrad::testing::forall;

/// Property: for random parameters, initial states, and step counts, the
/// three gradient estimators agree on the θ-gradient of Σ X_T within a
/// discretization-limited tolerance.
#[test]
fn property_gradient_estimators_agree() {
    forall("estimators-agree", 11, 8, |g| {
        let dim = g.usize_in(1, 4);
        let sde = ReplicatedSde::new(Example1, dim);
        let seed = g.usize_in(0, 1_000_000) as u64;
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let n = 3000;

        // One problem definition, four estimators — the API keeps the
        // Brownian path matched across all of them.
        let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
        let step = StepControl::Steps(n);
        let adj = prob
            .sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), step)
            .unwrap();
        let bp_mil =
            prob.sensitivity_sum(&SensAlg::backprop(Method::MilsteinIto), step).unwrap();
        let bp_eul = prob
            .sensitivity_sum(&SensAlg::backprop(Method::EulerMaruyama), step)
            .unwrap();
        let fw = prob.sensitivity_sum(&SensAlg::ForwardPathwise, step).unwrap();

        for j in 0..theta.len() {
            let scale = bp_mil.dtheta[j].abs().max(1.0);
            // Adjoint vs Milstein-backprop: same strong-order-1.0 target,
            // agree up to discretization.
            if (adj.dtheta[j] - bp_mil.dtheta[j]).abs() / scale > 0.05 {
                return Err(format!(
                    "seed {seed} dim {dim} θ[{j}]: adjoint {} vs backprop {}",
                    adj.dtheta[j], bp_mil.dtheta[j]
                ));
            }
            // Pathwise vs Euler-backprop: forward- and reverse-mode of the
            // SAME discrete computation — must agree to round-off.
            if (fw.dtheta[j] - bp_eul.dtheta[j]).abs() / scale > 1e-6 {
                return Err(format!(
                    "seed {seed} θ[{j}]: pathwise {} vs euler-backprop {} (should be exact)",
                    fw.dtheta[j], bp_eul.dtheta[j]
                ));
            }
        }
        Ok(())
    });
}

/// Property: the virtual tree and a stored path deliver statistically
/// identical increments — Kolmogorov-ish check on mean/variance over
/// random subintervals.
#[test]
fn property_tree_and_path_increment_laws_match() {
    forall("tree-path-laws", 12, 5, |g| {
        let t0 = g.f64_in(0.0, 0.2);
        let t1 = t0 + g.f64_in(0.3, 0.8);
        let n = 4000;
        let mut sum_t = 0.0;
        let mut sq_t = 0.0;
        let mut sum_p = 0.0;
        let mut sq_p = 0.0;
        for i in 0..n {
            let key = PrngKey::from_seed(7_000_000 + i);
            let mut tree = VirtualBrownianTree::new(key, 1, 0.0, 1.0, 1e-9);
            let inc = tree.increment(t0, t1)[0];
            sum_t += inc;
            sq_t += inc * inc;
            let mut path = BrownianPath::new(key, 1, 0.0, 1.0);
            let inc = path.increment(t0, t1)[0];
            sum_p += inc;
            sq_p += inc * inc;
        }
        let var_expect = t1 - t0;
        let var_t = sq_t / n as f64 - (sum_t / n as f64).powi(2);
        let var_p = sq_p / n as f64 - (sum_p / n as f64).powi(2);
        let tol = 6.0 * var_expect * (2.0 / n as f64).sqrt();
        if (var_t - var_expect).abs() > tol {
            return Err(format!("tree var {var_t} vs {var_expect} on [{t0},{t1}]"));
        }
        if (var_p - var_expect).abs() > tol {
            return Err(format!("path var {var_p} vs {var_expect} on [{t0},{t1}]"));
        }
        Ok(())
    });
}

/// Property: adjoint θ-gradients converge to the closed form for all
/// three paper problems at random setups.
#[test]
fn property_adjoint_matches_closed_form_all_problems() {
    fn check<P: ScalarSde + Copy>(problem: P, seed: u64) -> Result<(), String> {
        let dim = 3;
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .key(key)
            .sensitivity_sum(
                &SensAlg::StochasticAdjoint(AdjointConfig::default()),
                StepControl::Steps(4000),
            )
            .unwrap();
        let mut g_x0 = vec![0.0; dim];
        let mut g_th = vec![0.0; theta.len()];
        sde.analytic_loss_gradients(1.0, &x0, &theta, &out.w_terminal, &mut g_x0, &mut g_th);
        for j in 0..theta.len() {
            let rel = (out.dtheta[j] - g_th[j]).abs() / g_th[j].abs().max(1e-2);
            if rel > 0.03 {
                return Err(format!(
                    "{} seed {seed} θ[{j}]: {} vs analytic {} (rel {rel:.4})",
                    problem.name(),
                    out.dtheta[j],
                    g_th[j]
                ));
            }
        }
        Ok(())
    }
    forall("adjoint-closed-form", 13, 4, |g| {
        let seed = g.usize_in(0, 100_000) as u64;
        check(Example1, seed)?;
        check(Example2, seed + 1)?;
        check(Example3, seed + 2)
    });
}

/// End-to-end: train on GBM, checkpoint, reload, and verify the reloaded
/// parameters produce the identical ELBO on a held-out sequence.
#[test]
fn train_checkpoint_reload_roundtrip() {
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 1,
        latent_dim: 2,
        context_dim: 1,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 8,
        obs_noise_std: 0.05,
        ..Default::default()
    });
    let ds = gbm_generate(
        PrngKey::from_seed(5),
        &GbmConfig { n_series: 6, dt_obs: 0.1, ..Default::default() },
    );
    let idx: Vec<usize> = (0..5).collect();
    let cfg = TrainConfig {
        iters: 8,
        batch_size: 3,
        substeps: 2,
        exec: ExecConfig::new().threads(2),
        val_every: 0,
        ..Default::default()
    };
    let report = train_latent_sde(&model, &ds, &idx, &[], &cfg, None);

    let dir = std::env::temp_dir().join("sdegrad_integration");
    let path = dir.join("ckpt.bin");
    save_params(&path, &report.final_params).unwrap();
    let reloaded = load_params(&path).unwrap();
    assert_eq!(reloaded, report.final_params);

    let ecfg = ElboConfig { substeps: 2, kl_weight: 1.0, ..ElboConfig::default() };
    let key = PrngKey::from_seed(99);
    let a = elbo_step(&model, &report.final_params, &ds.times, ds.series(5), key, &ecfg);
    let b = elbo_step(&model, &reloaded, &ds.times, ds.series(5), key, &ecfg);
    assert_eq!(a.loss, b.loss, "reloaded params changed the loss");
}

/// The adjoint through a virtual tree is bit-deterministic: same seed →
/// identical gradients, run to run.
#[test]
fn adjoint_with_tree_is_bit_deterministic() {
    let sde = ReplicatedSde::new(Example2, 4);
    let key = PrngKey::from_seed(17);
    let (theta, x0) = sample_experiment_setup(key, 4, 1);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .noise(NoiseMode::VirtualTree { tol: 1e-7 });
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let a = prob.sensitivity_sum(&alg, StepControl::Steps(500)).unwrap();
    let b = prob.sensitivity_sum(&alg, StepControl::Steps(500)).unwrap();
    assert_eq!(a.dtheta, b.dtheta);
    assert_eq!(a.dz0, b.dz0);
    assert_eq!(a.z_terminal, b.z_terminal);
}

/// Longer horizons and non-unit time origins work (regression guard for
/// hidden `[0,1]` assumptions).
#[test]
fn nonstandard_time_horizons() {
    let sde = ReplicatedSde::new(Example3, 2);
    let key = PrngKey::from_seed(23);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let (t0, t1) = (0.5, 3.0);
    let prob = SdeProblem::new(&sde, &x0, (t0, t1)).params(&theta).key(key);
    let step = StepControl::Steps(3000);
    let out = prob
        .sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), step)
        .unwrap();
    // Closed form of Example 3 holds from t0=0; for t0=0.5 compare against
    // backprop (exact for the discretization) instead.
    let bp =
        prob.sensitivity_sum(&SensAlg::backprop(Method::MilsteinIto), step).unwrap();
    for j in 0..theta.len() {
        let rel = (out.dtheta[j] - bp.dtheta[j]).abs() / bp.dtheta[j].abs().max(1e-2);
        assert!(rel < 0.05, "θ[{j}]: adjoint {} vs backprop {}", out.dtheta[j], bp.dtheta[j]);
    }
}
