//! The constant-memory claim, pinned: checkpointed backprop must equal
//! the full-tape engine **exact-f64** — same gradients, same solver
//! accounting — for every scheme, schedule, noise spec, mirror flag, and
//! batch layout, while its peak tape memory obeys the schedule (O(√n)
//! for `Sqrt`, an explicit cap for `Budget`) and the recomputation cost
//! is visible in `stats.recompute_nfe`.
//!
//! The equality is not a tolerance check: the backward walk processes
//! the same steps in the same order through the same kernel for every
//! schedule, so any difference at all is a replay bug.

use sdegrad::api::{
    sensitivity_batch, Checkpointing, Gradients, NoiseSpec, SdeProblem, SensAlg, StepControl,
};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;
use sdegrad::sde::problems::{sample_experiment_setup, Example1, Example2};
use sdegrad::sde::ReplicatedSde;
use sdegrad::solvers::Method;

fn assert_same_gradients(a: &Gradients, b: &Gradients, ctx: &str) {
    assert_eq!(a.dtheta, b.dtheta, "dtheta: {ctx}");
    assert_eq!(a.dz0, b.dz0, "dz0: {ctx}");
    assert_eq!(a.z_terminal, b.z_terminal, "z_terminal: {ctx}");
    assert_eq!(a.z0_reconstructed, b.z0_reconstructed, "z0_reconstructed: {ctx}");
    assert_eq!(a.w_terminal, b.w_terminal, "w_terminal: {ctx}");
}

/// The core equivalence matrix: scheme × noise spec × mirror × schedule,
/// every cell exactly equal to the full tape — including the degenerate
/// budgets 1 (single-step leaves) and n (flat plan just under the tape).
#[test]
fn every_schedule_is_exactly_the_full_tape() {
    let n = 97; // prime: uneven segment partitions in every schedule
    let dim = 3;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3001);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let schedules = [
        Checkpointing::Sqrt,
        Checkpointing::Log,
        Checkpointing::Budget { max_live_steps: 1 },
        Checkpointing::Budget { max_live_steps: 3 },
        Checkpointing::Budget { max_live_steps: n },
    ];
    for method in [Method::EulerMaruyama, Method::MilsteinIto, Method::Heun] {
        for (noise, mirror) in [
            (NoiseSpec::StoredPath, false),
            (NoiseSpec::StoredPath, true),
            (NoiseSpec::VirtualTree { tol: 1e-8 }, false),
            (NoiseSpec::VirtualTree { tol: 1e-8 }, true),
        ] {
            let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0))
                .params(&theta)
                .key(key)
                .noise(noise)
                .mirror(mirror);
            let tape =
                prob.sensitivity_sum(&SensAlg::backprop(method), StepControl::Steps(n)).unwrap();
            assert_eq!(tape.stats.recompute_nfe, 0, "the tape recomputes nothing");
            for ck in schedules {
                let g = prob
                    .sensitivity_sum(
                        &SensAlg::Backprop { method, checkpointing: ck },
                        StepControl::Steps(n),
                    )
                    .unwrap();
                let ctx = format!("{method:?} / {noise:?} / mirror={mirror} / {ck:?}");
                assert_same_gradients(&g, &tape, &ctx);
                // A schedule changes *when* inputs are materialized, never
                // what is computed: the solver accounting is
                // schedule-invariant...
                assert_eq!(g.stats.forward, tape.stats.forward, "forward stats: {ctx}");
                assert_eq!(g.stats.backward, tape.stats.backward, "backward stats: {ctx}");
                // ...recomputation only shows in its own counter, and the
                // whole point is a smaller live tape.
                assert!(g.stats.recompute_nfe > 0, "{ctx}");
                assert!(
                    g.stats.peak_tape_bytes < tape.stats.peak_tape_bytes,
                    "peak {} vs tape {}: {ctx}",
                    g.stats.peak_tape_bytes,
                    tape.stats.peak_tape_bytes
                );
            }
        }
    }
}

/// Same pin on the nonlinear §7.1 problem (state-dependent diffusion
/// stresses the replayed VJP inputs the most).
#[test]
fn schedules_agree_on_the_nonlinear_problem() {
    let n = 128;
    let sde = ReplicatedSde::new(Example2, 2);
    let key = PrngKey::from_seed(3050);
    let (theta, x0) = sample_experiment_setup(key, 2, 1);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .noise(NoiseSpec::VirtualTree { tol: 1e-8 });
    for method in [Method::EulerMaruyama, Method::Heun] {
        let tape =
            prob.sensitivity_sum(&SensAlg::backprop(method), StepControl::Steps(n)).unwrap();
        let g = prob
            .sensitivity_sum(
                &SensAlg::Backprop { method, checkpointing: Checkpointing::Sqrt },
                StepControl::Steps(n),
            )
            .unwrap();
        assert_same_gradients(&g, &tape, &format!("Example2 {method:?}"));
    }
}

/// A budget the tape fits in *is* the tape: zero recomputation, identical
/// accounting.
#[test]
fn budget_above_n_degenerates_to_the_tape() {
    let n = 64;
    let sde = ReplicatedSde::new(Example1, 2);
    let key = PrngKey::from_seed(3070);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
    let tape = prob
        .sensitivity_sum(&SensAlg::backprop(Method::MilsteinIto), StepControl::Steps(n))
        .unwrap();
    let g = prob
        .sensitivity_sum(
            &SensAlg::Backprop {
                method: Method::MilsteinIto,
                checkpointing: Checkpointing::Budget { max_live_steps: n + 1 },
            },
            StepControl::Steps(n),
        )
        .unwrap();
    assert_same_gradients(&g, &tape, "budget=n+1");
    assert_eq!(g.stats.recompute_nfe, 0);
    assert_eq!(g.stats.peak_tape_bytes, tape.stats.peak_tape_bytes);
    assert_eq!(g.stats.noise_memory, tape.stats.noise_memory);
}

/// Batched checkpointed backprop == per-path scalar runs, bit for bit and
/// stat for stat, across chunk boundaries (67 paths = chunks of 32/32/3)
/// and mixed mirror flags, for tape and non-tape schedules alike.
#[test]
fn batched_checkpointed_backprop_equals_scalar_per_path() {
    let dim = 2;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3100);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let step = StepControl::Steps(60);
    for ck in [
        Checkpointing::Tape,
        Checkpointing::Sqrt,
        Checkpointing::Budget { max_live_steps: 5 },
    ] {
        let alg = SensAlg::Backprop { method: Method::MilsteinIto, checkpointing: ck };
        let probs: Vec<_> = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .replicates(PrngKey::from_seed(3101), 67)
            .into_iter()
            .enumerate()
            .map(|(i, p)| if i % 3 == 0 { p.mirror(true) } else { p })
            .collect();
        let batch = sensitivity_batch(&probs, &alg, step, ExecConfig::default());
        assert_eq!(batch.len(), probs.len());
        for (i, p) in probs.iter().enumerate() {
            let seq = p.sensitivity_sum(&alg, step).unwrap();
            let b = batch[i].as_ref().unwrap();
            let ctx = format!("{ck:?} path {i}");
            assert_same_gradients(b, &seq, &ctx);
            assert_eq!(b.stats.forward, seq.stats.forward, "forward stats: {ctx}");
            assert_eq!(b.stats.backward, seq.stats.backward, "backward stats: {ctx}");
            assert_eq!(b.stats.noise_memory, seq.stats.noise_memory, "noise_memory: {ctx}");
            assert_eq!(
                b.stats.peak_tape_bytes, seq.stats.peak_tape_bytes,
                "peak_tape_bytes: {ctx}"
            );
            assert_eq!(b.stats.recompute_nfe, seq.stats.recompute_nfe, "recompute: {ctx}");
        }
    }
}

/// The headline regime: a ≥10⁵-step gradient under a hard live-step
/// budget, with virtual-tree noise so the whole run is O(budget) memory —
/// a horizon where holding the full tape is exactly what the subsystem
/// exists to avoid. The budget must be honored (leaf tape ≈ 2 floats per
/// live step per dim plus the bisection stack) and the gradients must
/// still be the exact values any other schedule produces.
#[test]
fn long_horizon_gradient_under_a_hard_memory_budget() {
    let n = 120_000;
    let dim = 2;
    let budget = 64;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3200);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .noise(NoiseSpec::VirtualTree { tol: 1e-6 });
    let g = prob
        .sensitivity_sum(
            &SensAlg::Backprop {
                method: Method::EulerMaruyama,
                checkpointing: Checkpointing::Budget { max_live_steps: budget },
            },
            StepControl::Steps(n),
        )
        .unwrap();
    let full_tape_bytes = (2 * n + 1) * dim * 8;
    assert!(
        g.stats.peak_tape_bytes <= (2 * budget + 24) * dim * 8,
        "budget violated: peak {} bytes",
        g.stats.peak_tape_bytes
    );
    assert!(
        g.stats.peak_tape_bytes * 500 < full_tape_bytes,
        "peak {} vs full tape {}",
        g.stats.peak_tape_bytes,
        full_tape_bytes
    );
    assert!(g.stats.recompute_nfe > 0);
    assert!(g.dtheta.iter().chain(&g.dz0).all(|v| v.is_finite()));

    // Exactness at this horizon too: a structurally different schedule
    // (flat √n vs deep bisection) must reproduce every bit.
    let g2 = prob
        .sensitivity_sum(
            &SensAlg::Backprop {
                method: Method::EulerMaruyama,
                checkpointing: Checkpointing::Sqrt,
            },
            StepControl::Steps(n),
        )
        .unwrap();
    assert_same_gradients(&g, &g2, "budget-64 vs sqrt at 120k steps");
}

/// Fig-style scaling ladder: under the `Sqrt` schedule the measured peak
/// tape bytes grow like √n — ~2× per 4× steps, ~8× over a 64× ladder —
/// where the full tape would grow 4× and 64×.
#[test]
fn sqrt_schedule_memory_scales_as_root_n() {
    let dim = 2;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3300);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
    let mut peaks = Vec::new();
    for &n in &[256usize, 1024, 4096, 16384] {
        let g = prob
            .sensitivity_sum(
                &SensAlg::Backprop {
                    method: Method::EulerMaruyama,
                    checkpointing: Checkpointing::Sqrt,
                },
                StepControl::Steps(n),
            )
            .unwrap();
        // Absolute bound: √n checkpoints + a (2√n+1)-float-per-dim leaf.
        let bound = (4.0 * (n as f64).sqrt()) as usize * dim * 8;
        assert!(
            g.stats.peak_tape_bytes <= bound,
            "n={n}: peak {} > {bound}",
            g.stats.peak_tape_bytes
        );
        peaks.push(g.stats.peak_tape_bytes as f64);
    }
    for w in peaks.windows(2) {
        let ratio = w[1] / w[0];
        assert!(ratio < 2.6, "4x steps should cost ~2x memory: peaks {peaks:?}");
    }
    assert!(peaks[3] / peaks[0] < 12.0, "64x steps should cost ~8x memory: peaks {peaks:?}");
}
