//! The batched SoA execution engine must be *bit-identical* to the scalar
//! engine: a batch of one equals a plain [`SdeProblem::solve`] /
//! [`SdeProblem::sensitivity_sum`], and a batch of B equals a sequential
//! per-path loop path-for-path — exact f64 equality throughout, for any
//! thread count (chunk partitioning is fixed and each path's floats are
//! independent of its neighbours, so thread scheduling cannot change a
//! single bit; re-running pins run-to-run determinism too).

use sdegrad::adjoint::{AdjointConfig, NoiseMode};
use sdegrad::api::{
    sensitivity_batch, sensitivity_batch_per_path, solve_batch, solve_batch_per_path, SaveAt,
    SdeProblem, SensAlg, SolveOptions, StepControl,
};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;
use sdegrad::sde::ou::OrnsteinUhlenbeck;
use sdegrad::sde::problems::{sample_experiment_setup, Example1, Example2, Example3};
use sdegrad::sde::{BatchSdeVjp, ReplicatedSde, ScalarSde};
use sdegrad::solvers::Method;

// ---------------------------------------------------------------------------
// Forward solves.
// ---------------------------------------------------------------------------

/// Batch-of-1 `solve_batch` == scalar `SdeProblem::solve`, bit for bit,
/// on the §7.1 problems across every scheme.
#[test]
fn batch_of_one_solve_is_bit_identical_to_scalar_engine() {
    fn check<P: ScalarSde + Copy>(problem: P, dim: usize, seed: u64, method: Method) {
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
        let opts = SolveOptions::fixed(method, 173);

        let scalar = prob.solve(&opts);
        let batch = solve_batch(std::slice::from_ref(&prob), &opts);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].states, scalar.states, "{}", method.name());
        assert_eq!(batch[0].times, scalar.times);
        assert_eq!(batch[0].stats, scalar.stats);
    }
    check(Example1, 3, 11, Method::EulerMaruyama);
    check(Example1, 3, 12, Method::MilsteinIto);
    check(Example2, 2, 13, Method::Heun);
    check(Example3, 4, 14, Method::MilsteinIto);
}

/// Batch-of-1 on OU (shared-θ, additive noise) including the dense save
/// path and the replay handle.
#[test]
fn batch_of_one_dense_solve_matches_scalar_on_ou() {
    let ou = OrnsteinUhlenbeck::new(3);
    let theta = [1.2, 0.4, 0.6];
    let z0 = [0.1, -0.3, 0.8];
    let key = PrngKey::from_seed(21);
    let prob = SdeProblem::new(&ou, &z0, (0.0, 2.0)).params(&theta).key(key);
    let opts = SolveOptions::fixed(Method::Heun, 128).save(SaveAt::Dense);

    let mut scalar = prob.solve(&opts);
    let mut batch = solve_batch(std::slice::from_ref(&prob), &opts);
    assert_eq!(batch[0].states, scalar.states);
    assert_eq!(batch[0].times, scalar.times);
    // The replay handle carries the same realized path.
    assert_eq!(batch[0].w(2.0), scalar.w(2.0));
    assert_eq!(batch[0].w(0.37), scalar.w(0.37));
}

/// Batch-of-B equals a sequential per-path loop path-for-path, exactly —
/// across batch sizes that exercise partial chunks and multiple chunks —
/// and replicates with distinct keys realize distinct paths.
#[test]
fn batch_solve_equals_sequential_loop_path_for_path() {
    let sde = ReplicatedSde::new(Example1, 3);
    let key = PrngKey::from_seed(61);
    let (theta, x0) = sample_experiment_setup(key, 3, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let opts = SolveOptions::fixed(Method::MilsteinIto, 200);

    for n in [1usize, 5, 32, 33, 97] {
        let replicates = prob.replicates(PrngKey::from_seed(62), n);
        let batch_a = solve_batch(&replicates, &opts);
        let batch_b = solve_batch(&replicates, &opts);
        let sequential: Vec<_> = replicates.iter().map(|p| p.solve(&opts)).collect();
        assert_eq!(batch_a.len(), n);
        for i in 0..n {
            assert_eq!(batch_a[i].states, batch_b[i].states, "run-to-run at {i} (n={n})");
            assert_eq!(batch_a[i].states, sequential[i].states, "vs sequential at {i} (n={n})");
            assert_eq!(batch_a[i].stats, sequential[i].stats, "stats at {i} (n={n})");
        }
    }
    let replicates = prob.replicates(PrngKey::from_seed(62), 4);
    let sols = solve_batch(&replicates, &opts);
    assert_ne!(sols[0].states, sols[1].states, "replicates must differ");
}

/// The per-path engine (thread-per-path baseline) agrees with the batched
/// engine exactly — the throughput bench's correctness precondition.
#[test]
fn per_path_engine_matches_batched_engine() {
    let sde = ReplicatedSde::new(Example2, 2);
    let key = PrngKey::from_seed(71);
    let (theta, x0) = sample_experiment_setup(key, 2, 1);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let opts = SolveOptions::fixed(Method::Heun, 150);
    let replicates = prob.replicates(PrngKey::from_seed(72), 23);
    let batched = solve_batch(&replicates, &opts);
    let per_path = solve_batch_per_path(&replicates, &opts);
    for (a, b) in batched.iter().zip(&per_path) {
        assert_eq!(a.states, b.states);
        assert_eq!(a.stats, b.stats);
    }
}

/// Mixed per-path mirror flags ride the batched kernel (mirroring is a
/// per-source property); a mirrored batch member realizes the negated
/// path of its unmirrored twin.
#[test]
fn mirrored_paths_batch_with_unmirrored_ones() {
    let sde = ReplicatedSde::new(Example3, 2);
    let key = PrngKey::from_seed(81);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let base = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
    let pair = vec![base.clone(), base.clone().mirror(true)];
    let opts = SolveOptions::fixed(Method::MilsteinIto, 100);

    let mut batch = solve_batch(&pair, &opts);
    let seq: Vec<_> = pair.iter().map(|p| p.solve(&opts)).collect();
    assert_eq!(batch[0].states, seq[0].states);
    assert_eq!(batch[1].states, seq[1].states);
    let (w_plus, w_minus) = (batch[0].w(1.0), batch[1].w(1.0));
    for (a, b) in w_plus.iter().zip(&w_minus) {
        assert_eq!(*a, -*b, "mirror must negate the realized path");
    }
}

// ---------------------------------------------------------------------------
// Gradients.
// ---------------------------------------------------------------------------

fn check_gradient_batch<S>(sde: &S, theta: &[f64], z0: &[f64], seed: u64, noise: NoiseMode)
where
    S: BatchSdeVjp + Sync + ?Sized,
{
    let prob = SdeProblem::new(sde, z0, (0.0, 1.0)).params(theta).noise(noise);
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let step = StepControl::Steps(150);
    for n in [1usize, 9, 40] {
        let replicates = prob.replicates(PrngKey::from_seed(seed), n);
        let batch = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
        for (i, p) in replicates.iter().enumerate() {
            let seq = p.sensitivity_sum(&alg, step).unwrap();
            let b = batch[i].as_ref().unwrap();
            assert_eq!(b.dtheta, seq.dtheta, "dtheta at {i} (n={n})");
            assert_eq!(b.dz0, seq.dz0, "dz0 at {i} (n={n})");
            assert_eq!(b.z_terminal, seq.z_terminal, "z_terminal at {i} (n={n})");
            assert_eq!(b.z0_reconstructed, seq.z0_reconstructed, "z0_rec at {i} (n={n})");
            assert_eq!(b.w_terminal, seq.w_terminal, "w_terminal at {i} (n={n})");
            assert_eq!(b.stats.forward, seq.stats.forward, "fwd stats at {i} (n={n})");
            assert_eq!(b.stats.backward, seq.stats.backward, "bwd stats at {i} (n={n})");
            assert_eq!(b.stats.noise_memory, seq.stats.noise_memory, "memory at {i} (n={n})");
        }
    }
}

/// Batched stochastic adjoint == per-path scalar adjoint, exactly, on all
/// three §7.1 problems (stored-path noise).
#[test]
fn batched_adjoint_matches_scalar_adjoint_section71() {
    let gbm = ReplicatedSde::new(Example1, 3);
    let key = PrngKey::from_seed(101);
    let (theta, x0) = sample_experiment_setup(key, 3, 2);
    check_gradient_batch(&gbm, &theta, &x0, 102, NoiseMode::StoredPath);

    let ex2 = ReplicatedSde::new(Example2, 2);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(103), 2, 1);
    check_gradient_batch(&ex2, &theta, &x0, 104, NoiseMode::StoredPath);

    let ex3 = ReplicatedSde::new(Example3, 4);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(105), 4, 2);
    check_gradient_batch(&ex3, &theta, &x0, 106, NoiseMode::StoredPath);
}

/// Same pin on OU (shared θ across dimensions — exercises cross-path
/// independence of the per-path `a_θ` rows) and under virtual-tree noise
/// (the O(1)-memory spec flows through the batched kernel unchanged).
#[test]
fn batched_adjoint_matches_scalar_on_ou_and_virtual_tree() {
    let ou = OrnsteinUhlenbeck::new(2);
    check_gradient_batch(&ou, &[1.5, 0.7, 0.3], &[0.4, -0.2], 111, NoiseMode::StoredPath);

    let gbm = ReplicatedSde::new(Example1, 2);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(112), 2, 2);
    check_gradient_batch(&gbm, &theta, &x0, 113, NoiseMode::VirtualTree { tol: 1e-6 });
}

/// The per-path gradient engine agrees with the batched one — for the
/// batched algorithms (adjoint, backprop) and the per-path fallbacks
/// (pathwise, antithetic) alike — producing results in input order.
#[test]
fn gradient_fallbacks_and_per_path_engine_agree() {
    let sde = ReplicatedSde::new(Example1, 2);
    let key = PrngKey::from_seed(121);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let step = StepControl::Steps(80);
    let replicates = prob.replicates(PrngKey::from_seed(122), 7);

    for alg in [
        SensAlg::StochasticAdjoint(AdjointConfig::default()),
        SensAlg::backprop(Method::MilsteinIto),
        SensAlg::ForwardPathwise,
        SensAlg::Antithetic { base: AdjointConfig::default() },
    ] {
        let batched = sensitivity_batch(&replicates, &alg, step, ExecConfig::default());
        let per_path = sensitivity_batch_per_path(&replicates, &alg, step);
        for (i, (a, b)) in batched.iter().zip(&per_path).enumerate() {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dtheta, b.dtheta, "{} at {i}", alg.name());
            assert_eq!(a.dz0, b.dz0, "{} at {i}", alg.name());
        }
    }
}

/// Validation errors surface per problem from the batched entry point
/// exactly as from the scalar one.
#[test]
fn batched_sensitivity_propagates_validation_errors() {
    use sdegrad::api::ProblemError;
    let sde = ReplicatedSde::new(Example1, 2);
    let key = PrngKey::from_seed(131);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .noise(NoiseMode::VirtualTree { tol: 1e-6 });
    let replicates = prob.replicates(PrngKey::from_seed(132), 3);
    // Backprop through a Stratonovich–Milstein step has no VJP kernel:
    // every slot reports UnsupportedMethod.
    let outs = sensitivity_batch(
        &replicates,
        &SensAlg::backprop(Method::MilsteinStrat),
        StepControl::Steps(10),
        ExecConfig::default(),
    );
    assert_eq!(outs.len(), 3);
    for o in outs {
        assert!(matches!(o.unwrap_err(), ProblemError::UnsupportedMethod { .. }));
    }
    // Adaptive stepping is rejected per problem.
    let outs = sensitivity_batch(
        &replicates,
        &SensAlg::StochasticAdjoint(AdjointConfig::default()),
        StepControl::Adaptive(Default::default()),
        ExecConfig::default(),
    );
    for o in outs {
        assert!(matches!(o.unwrap_err(), ProblemError::AdaptiveSensitivityUnsupported));
    }
}

/// Heterogeneous problem sets (different θ per problem) silently take the
/// per-path fallback and still match sequential execution exactly.
#[test]
fn non_batchable_sets_fall_back_to_per_path_results() {
    let sde = ReplicatedSde::new(Example1, 2);
    let key = PrngKey::from_seed(141);
    let (theta_a, x0) = sample_experiment_setup(key, 2, 2);
    let theta_b: Vec<f64> = theta_a.iter().map(|v| v * 1.1).collect();
    let mixed = vec![
        SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta_a).key(key),
        SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta_b).key(key.fold_in(1)),
    ];
    let opts = SolveOptions::fixed(Method::MilsteinIto, 64);
    let batch = solve_batch(&mixed, &opts);
    for (sol, p) in batch.iter().zip(&mixed) {
        assert_eq!(sol.states, p.solve(&opts).states);
    }
}
