//! Persistent-executor and Brownian-tree-cache pins.
//!
//! The process-wide work-stealing pool (`runtime::scoped_map`) and the
//! virtual-tree ancestor node cache are pure *scheduling/speed* layers:
//! neither may change a single computed bit. This suite pins that
//! contract from the public API:
//!
//! * batched solves and gradients are **exact-f64-identical** to the
//!   sequential scalar loop for every pool size × every tree-cache
//!   capacity combination (including capacity 0 = cache disabled);
//! * checkpointed-backprop segment replay equals the full tape under
//!   every cache capacity;
//! * the cache's amortized-draw contract holds on a dyadic sweep
//!   (`bridge_calls ≤ 2·steps` cached, strictly more uncached);
//! * the minibatch ELBO engine gives identical results on the pool for
//!   every worker count;
//! * consecutive batched calls **reuse** pool workers instead of
//!   spawning new threads (asserted via the thread-attributed spawn
//!   counter, so concurrent tests sharing the pool cannot race it);
//! * a panicking task closure propagates to the caller — no hang, no
//!   dead workers — and the pool keeps serving.
//!
//! Tests that mutate the process-wide worker count serialize on `KNOB`
//! (integration tests share one process, hence one pool). Tests that
//! only *read* results need no lock — any width computes the same bits,
//! which is exactly what they assert.

use std::sync::Mutex;

use sdegrad::adjoint::AdjointConfig;
use sdegrad::api::{
    sensitivity_batch, solve_batch, Checkpointing, NoiseSpec, SdeProblem, SensAlg, SolveOptions,
    StepControl,
};
use sdegrad::latent::{elbo_step_batch, ElboConfig, LatentSdeConfig, LatentSdeModel};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::{scoped_map, set_worker_count, spawned_by_this_thread, worker_count, ExecConfig};
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::ReplicatedSde;
use sdegrad::solvers::Method;

/// Serializes tests that mutate the process-wide worker count.
static KNOB: Mutex<()> = Mutex::new(());

const POOL_SIZES: [usize; 3] = [1, 2, 8];
const CACHE_CAPS: [usize; 3] = [0, 4, 64];

fn gbm_problem(
    sde: &ReplicatedSde<Example1>,
    theta: &[f64],
    x0: &[f64],
    tol: f64,
) -> SdeProblem<'_, ReplicatedSde<Example1>> {
    SdeProblem::new(sde, x0, (0.0, 1.0))
        .params(theta)
        .noise(NoiseSpec::VirtualTree { tol })
}

/// Forward solves: for every (pool size × cache capacity), the batched
/// engine reproduces the sequential scalar loop bit-for-bit, and every
/// capacity produces the same bits as every other.
#[test]
fn solves_bit_identical_across_pool_sizes_and_cache_capacities() {
    let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let dim = 3;
    let sde = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(71), dim, 2);
    let prob = gbm_problem(&sde, &theta, &x0, 1e-7);
    let opts = SolveOptions::fixed(Method::MilsteinIto, 200);
    let n_paths = 41; // crosses the 32-path chunk boundary

    // Sequential scalar reference (default capacity, no pool).
    set_worker_count(1);
    let replicates = prob.replicates(PrngKey::from_seed(72), n_paths);
    let reference: Vec<Vec<f64>> =
        replicates.iter().map(|p| p.solve(&opts).states.clone()).collect();

    for pool in POOL_SIZES {
        set_worker_count(pool);
        for cap in CACHE_CAPS {
            let probs: Vec<_> =
                replicates.iter().map(|p| p.clone().tree_cache(cap)).collect();
            let sols = solve_batch(&probs, &opts);
            assert_eq!(sols.len(), n_paths);
            for (b, sol) in sols.iter().enumerate() {
                assert_eq!(
                    sol.states, reference[b],
                    "solve diverged at pool={pool} cache={cap} path={b}"
                );
            }
        }
    }
    set_worker_count(0);
}

/// Gradients (stochastic adjoint AND taped backprop): bit-identical to
/// the scalar `sensitivity_sum` for every pool size × cache capacity.
#[test]
fn gradients_bit_identical_across_pool_sizes_and_cache_capacities() {
    let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let dim = 2;
    let sde = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(73), dim, 2);
    let prob = gbm_problem(&sde, &theta, &x0, 1e-7);
    let step = StepControl::Steps(64);
    let n_paths = 35;
    let algs = [
        SensAlg::StochasticAdjoint(AdjointConfig {
            forward_method: Method::MilsteinIto,
            ..Default::default()
        }),
        SensAlg::Backprop {
            method: Method::MilsteinIto,
            checkpointing: Checkpointing::Sqrt,
        },
    ];
    let replicates = prob.replicates(PrngKey::from_seed(74), n_paths);

    for alg in &algs {
        set_worker_count(1);
        let reference: Vec<Vec<f64>> = replicates
            .iter()
            .map(|p| p.sensitivity_sum(alg, step).unwrap().dtheta)
            .collect();
        for pool in POOL_SIZES {
            set_worker_count(pool);
            for cap in CACHE_CAPS {
                let probs: Vec<_> =
                    replicates.iter().map(|p| p.clone().tree_cache(cap)).collect();
                let grads = sensitivity_batch(&probs, alg, step, ExecConfig::default());
                for (b, g) in grads.iter().enumerate() {
                    assert_eq!(
                        g.as_ref().unwrap().dtheta,
                        reference[b],
                        "{} diverged at pool={pool} cache={cap} path={b}",
                        alg.name()
                    );
                }
            }
        }
    }
    set_worker_count(0);
}

/// Checkpointed segment replay must stay exact-f64-identical to the full
/// tape under every cache capacity: each replayed segment re-queries the
/// tree through the cache, and a cached node is the same pure function
/// of `(key, path)` a fresh descent computes.
#[test]
fn checkpointed_replay_equals_full_tape_under_every_cache_capacity() {
    let dim = 2;
    let sde = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(75), dim, 2);
    let prob = gbm_problem(&sde, &theta, &x0, 1e-8);
    let step = StepControl::Steps(128);

    let tape = prob
        .clone()
        .sensitivity_sum(&SensAlg::backprop(Method::MilsteinIto), step)
        .unwrap();
    for cap in CACHE_CAPS {
        let ckpt = prob
            .clone()
            .tree_cache(cap)
            .sensitivity_sum(
                &SensAlg::Backprop {
                    method: Method::MilsteinIto,
                    checkpointing: Checkpointing::Sqrt,
                },
                step,
            )
            .unwrap();
        assert_eq!(ckpt.dtheta, tape.dtheta, "checkpointed dtheta diverged at cache={cap}");
        assert_eq!(ckpt.dz0, tape.dz0, "checkpointed dz0 diverged at cache={cap}");
    }
}

/// The amortized-draw contract, from the public API: a monotone sweep
/// over a dyadic grid costs ≤ 2 bridge draws per step with the cache on
/// (each tree node is drawn exactly once), while the cache-disabled tree
/// re-descends from the root and pays ≥ 3 draws per step.
#[test]
fn node_cache_amortizes_bridge_draws_on_dyadic_sweep() {
    let dim = 3;
    let sde = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(76), dim, 2);
    let steps = 256u64; // power of two → dyadic grid on [0, 1]
    let opts = SolveOptions::fixed(Method::EulerMaruyama, steps as usize);

    let cached = gbm_problem(&sde, &theta, &x0, 1e-9).key(PrngKey::from_seed(77)).solve(&opts);
    let uncached = gbm_problem(&sde, &theta, &x0, 1e-9)
        .key(PrngKey::from_seed(77))
        .tree_cache(0)
        .solve(&opts);
    assert_eq!(cached.states, uncached.states, "cache changed the solution");

    let (c, u) = (cached.noise.bridge_calls(), uncached.noise.bridge_calls());
    assert!(c <= 2 * steps, "cached sweep drew {c} bridges for {steps} steps (want ≤ {})", 2 * steps);
    assert!(u >= 3 * steps, "uncached sweep drew only {u} bridges for {steps} steps");
    assert!(c < u, "cache did not reduce draws ({c} vs {u})");
}

/// The minibatch ELBO engine computes identical losses and gradients on
/// the pool for every worker count (the trainer's determinism contract,
/// now routed through `runtime::scoped_map`).
#[test]
fn elbo_step_identical_across_pool_worker_counts() {
    let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(78));
    let n_obs = 4;
    let times: Vec<f64> = (0..n_obs).map(|k| 0.1 * k as f64).collect();
    let n_seqs = 5;
    let seqs: Vec<Vec<f64>> = (0..n_seqs)
        .map(|m| {
            let mut obs = vec![0.0; n_obs * 2];
            PrngKey::from_seed(79 + m as u64).fill_normal(0, &mut obs);
            obs
        })
        .collect();
    let obs_seqs: Vec<&[f64]> = seqs.iter().map(|s| s.as_slice()).collect();
    let keys: Vec<PrngKey> =
        (0..n_seqs).map(|m| PrngKey::from_seed(80).fold_in(m as u64)).collect();
    let cfg = ElboConfig { substeps: 2, ..ElboConfig::default() };

    set_worker_count(1);
    let reference = elbo_step_batch(&model, &params, &times, &obs_seqs, &keys, &cfg, 2, 1);
    for pool in POOL_SIZES {
        set_worker_count(pool);
        // The engine's own worker knob fans out through the pool too.
        for elbo_workers in [1, 4] {
            let out = elbo_step_batch(
                &model, &params, &times, &obs_seqs, &keys, &cfg, 2, elbo_workers,
            );
            assert_eq!(out.loss, reference.loss, "loss at pool={pool} workers={elbo_workers}");
            assert_eq!(out.grad, reference.grad, "grad at pool={pool} workers={elbo_workers}");
            assert_eq!(out.per_path_loss, reference.per_path_loss);
        }
    }
    set_worker_count(0);
}

/// Consecutive batched calls must reuse the persistent workers: after a
/// warmup call at a fixed width, further calls (batched solves and raw
/// `scoped_map` fan-outs) spawn no new threads.
#[test]
fn consecutive_batched_calls_reuse_pool_workers() {
    let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let dim = 2;
    let sde = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(81), dim, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let replicates = prob.replicates(PrngKey::from_seed(82), 40);
    let opts = SolveOptions::fixed(Method::EulerMaruyama, 50);

    set_worker_count(4);
    assert_eq!(worker_count(), 4);
    // Warmup to full width: the solve fans out only ceil(40/32) = 2
    // chunks, so a wide raw fan-out is what brings the pool to 4.
    // Spawn counts are thread-attributed (`spawned_by_this_thread`), so
    // sibling tests sharing the process-wide pool cannot race them.
    let _ = solve_batch(&replicates, &opts);
    let _ = scoped_map(32, usize::MAX, |i| i + 1);
    let after_warmup = spawned_by_this_thread();
    for _ in 0..3 {
        let _ = solve_batch(&replicates, &opts);
        let _ = scoped_map(32, usize::MAX, |i| i * 2);
    }
    assert_eq!(
        spawned_by_this_thread(),
        after_warmup,
        "pool spawned new workers on consecutive calls"
    );
    set_worker_count(0);
}

/// A panicking task closure must neither hang the caller (the
/// completion latch still drops) nor kill pool workers: the panic
/// resumes on the calling thread after the job retires, and the same
/// pool keeps producing bit-correct results afterwards.
#[test]
fn task_panic_propagates_and_pool_keeps_serving() {
    let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    set_worker_count(4);
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        scoped_map(48, usize::MAX, |i| {
            if i == 13 {
                panic!("injected task failure");
            }
            i * 3
        })
    }));
    assert!(caught.is_err(), "task panic must propagate to the caller");
    let out = scoped_map(48, usize::MAX, |i| i * 3);
    assert_eq!(out, (0..48).map(|i| i * 3).collect::<Vec<_>>());
    set_worker_count(0);
}
