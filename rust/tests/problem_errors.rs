//! Coverage for every `ProblemError` path of the sensitivity API: the
//! validation that replaced the legacy mid-solve panics must fire *before
//! any integration starts*, with the right variant, for every estimator
//! family — plus the acceptance side of the contract: every in-tree noise
//! spec (stored path, virtual tree, mirrored either way) is deterministic
//! to replay, so no current estimator/spec combination is rejected for
//! its noise.

use sdegrad::adjoint::AdjointConfig;
use sdegrad::api::{NoiseSpec, ProblemError, SdeProblem, SensAlg, StepControl};
use sdegrad::prng::PrngKey;
use sdegrad::sde::{Calculus, Sde, SdeVjp};
use sdegrad::solvers::{AdaptiveConfig, Method};

/// Itô-native multiplicative-noise SDE that implements the first-order
/// VJPs but *not* the Itô-correction VJP (`has_ito_correction_vjp`
/// stays at its `false` default) — the exact shape that used to panic
/// mid-solve under the legacy free functions.
struct ItoNoCorrection;

impl Sde for ItoNoCorrection {
    fn state_dim(&self) -> usize {
        1
    }
    fn param_dim(&self) -> usize {
        1
    }
    fn calculus(&self) -> Calculus {
        Calculus::Ito
    }
    fn drift(&self, _t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        out[0] = theta[0] * z[0];
    }
    fn diffusion(&self, _t: f64, z: &[f64], _theta: &[f64], out: &mut [f64]) {
        out[0] = 0.3 * z[0];
    }
    fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _theta: &[f64], out: &mut [f64]) {
        out[0] = 0.3;
    }
}

impl SdeVjp for ItoNoCorrection {
    fn drift_vjp(
        &self,
        _t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        out_z[0] += a[0] * theta[0];
        out_theta[0] += a[0] * z[0];
    }
    fn diffusion_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        out_z[0] += a[0] * 0.3;
    }
}

/// Same system declared Stratonovich-native (additionally claims the
/// correction VJP so only the calculus check can fire).
struct StratNative;

impl Sde for StratNative {
    fn state_dim(&self) -> usize {
        1
    }
    fn param_dim(&self) -> usize {
        1
    }
    fn calculus(&self) -> Calculus {
        Calculus::Stratonovich
    }
    fn drift(&self, _t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        out[0] = theta[0] * z[0];
    }
    fn diffusion(&self, _t: f64, z: &[f64], _theta: &[f64], out: &mut [f64]) {
        out[0] = 0.3 * z[0];
    }
    fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _theta: &[f64], out: &mut [f64]) {
        out[0] = 0.3;
    }
}

impl SdeVjp for StratNative {
    fn drift_vjp(
        &self,
        _t: f64,
        z: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        out_z[0] += a[0] * theta[0];
        out_theta[0] += a[0] * z[0];
    }
    fn diffusion_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
        out_z[0] += a[0] * 0.3;
    }
    fn has_ito_correction_vjp(&self) -> bool {
        true
    }
    fn ito_correction_vjp(
        &self,
        _t: f64,
        _z: &[f64],
        _theta: &[f64],
        _a: &[f64],
        _out_z: &mut [f64],
        _out_theta: &mut [f64],
    ) {
    }
}

fn prob<S: SdeVjp>(sde: &S) -> SdeProblem<'_, S> {
    SdeProblem::new(sde, &[1.0], (0.0, 1.0)).params(&[0.5]).key(PrngKey::from_seed(1))
}

const STEPS: StepControl = StepControl::Steps(8);

// ---------------------------------------------------------------------------
// MissingItoCorrectionVjp — surfaced before integration, not mid-solve.
// ---------------------------------------------------------------------------

#[test]
fn adjoint_family_requires_ito_correction_vjp() {
    let sde = ItoNoCorrection;
    let p = prob(&sde);
    for alg in [
        SensAlg::StochasticAdjoint(AdjointConfig::default()),
        SensAlg::Antithetic { base: AdjointConfig::default() },
    ] {
        let err = p.sensitivity_sum(&alg, STEPS).unwrap_err();
        assert_eq!(
            err,
            ProblemError::MissingItoCorrectionVjp { algorithm: alg.name() },
            "alg {}",
            alg.name()
        );
        // The message should tell the implementor what to do.
        assert!(err.to_string().contains("ito_correction_vjp"), "msg: {err}");
    }
}

#[test]
fn milstein_backprop_requires_ito_correction_vjp_but_euler_does_not() {
    let sde = ItoNoCorrection;
    let p = prob(&sde);
    let err = p
        .sensitivity_sum(&SensAlg::backprop(Method::MilsteinIto), STEPS)
        .unwrap_err();
    assert_eq!(err, ProblemError::MissingItoCorrectionVjp { algorithm: "Backprop" });
    // Euler backprop never touches second derivatives of σ: it must run.
    let ok = p.sensitivity_sum(&SensAlg::backprop(Method::EulerMaruyama), STEPS);
    assert!(ok.is_ok(), "euler backprop should not need the correction VJP: {ok:?}");
}

// ---------------------------------------------------------------------------
// Noise replay — every in-tree spec is deterministic, so the taped family
// honors tree and mirror specs instead of rejecting them.
// ---------------------------------------------------------------------------

#[test]
fn taped_estimators_accept_virtual_tree_noise() {
    // The virtual tree is a pure function of (key, t): any segment replay
    // is bit-identical to the first pass by construction, so the taped
    // family runs on it — and is run-to-run deterministic.
    let sde = ItoNoCorrection;
    let p = prob(&sde).noise(NoiseSpec::VirtualTree { tol: 1e-8 });
    for alg in [
        SensAlg::backprop(Method::EulerMaruyama),
        SensAlg::ForwardPathwise,
    ] {
        let a = p
            .sensitivity_sum(&alg, STEPS)
            .unwrap_or_else(|e| panic!("{} must accept tree noise: {e}", alg.name()));
        let b = p.sensitivity_sum(&alg, STEPS).unwrap();
        assert_eq!(a.dtheta, b.dtheta, "alg {}", alg.name());
        assert_eq!(a.dz0, b.dz0, "alg {}", alg.name());
    }
}

#[test]
fn taped_estimators_accept_mirrored_problems() {
    // Mirroring is a deterministic negation of the realized path — equally
    // replayable. The mirrored run must realize the negated path (and, in
    // general, different gradients) while both runs succeed.
    let sde = ItoNoCorrection;
    let base = prob(&sde);
    let mirrored = prob(&sde).mirror(true);
    for alg in [
        SensAlg::backprop(Method::EulerMaruyama),
        SensAlg::ForwardPathwise,
    ] {
        let plus = base
            .sensitivity_sum(&alg, STEPS)
            .unwrap_or_else(|e| panic!("{}: {e}", alg.name()));
        let minus = mirrored
            .sensitivity_sum(&alg, STEPS)
            .unwrap_or_else(|e| panic!("{} must accept mirror: {e}", alg.name()));
        assert_eq!(plus.w_terminal[0], -minus.w_terminal[0], "alg {}", alg.name());
        assert_ne!(plus.dtheta, minus.dtheta, "alg {}", alg.name());
    }
}

#[test]
fn adjoint_family_accepts_virtual_tree_noise() {
    // The same spec the taped family rejects is the adjoint's O(1)-memory
    // headline feature — it must pass validation (and run) here. Uses the
    // Stratonovich-native system so no correction VJP is involved.
    let sde = StratNative;
    let p = prob(&sde).noise(NoiseSpec::VirtualTree { tol: 1e-8 });
    let out = p.sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), STEPS);
    assert!(out.is_ok(), "{out:?}");
}

// ---------------------------------------------------------------------------
// UnsupportedMethod / CalculusMismatch / AdaptiveSensitivityUnsupported.
// ---------------------------------------------------------------------------

#[test]
fn backprop_rejects_non_backproppable_schemes() {
    let sde = ItoNoCorrection;
    let p = prob(&sde);
    let method = Method::MilsteinStrat;
    let err = p.sensitivity_sum(&SensAlg::backprop(method), STEPS).unwrap_err();
    assert_eq!(err, ProblemError::UnsupportedMethod { algorithm: "Backprop", method });
    assert!(err.to_string().contains(method.name()), "msg: {err}");
}

#[test]
fn heun_backprop_needs_correction_vjp_only_for_ito_native_systems() {
    // Heun steps the Stratonovich form: an Itô-native SDE is first
    // drift-converted, and differentiating that conversion needs the
    // Itô-correction VJP.
    let sde = ItoNoCorrection;
    let err =
        prob(&sde).sensitivity_sum(&SensAlg::backprop(Method::Heun), STEPS).unwrap_err();
    assert_eq!(err, ProblemError::MissingItoCorrectionVjp { algorithm: "Backprop" });
    // Stratonovich-native systems are Heun's natural pairing: must run.
    let sde = StratNative;
    let ok = prob(&sde).sensitivity_sum(&SensAlg::backprop(Method::Heun), STEPS);
    assert!(ok.is_ok(), "heun backprop on a Stratonovich-native SDE: {ok:?}");
}

#[test]
fn taped_estimators_require_ito_native_systems() {
    let sde = StratNative;
    let p = prob(&sde);
    let err = p
        .sensitivity_sum(&SensAlg::backprop(Method::EulerMaruyama), STEPS)
        .unwrap_err();
    assert_eq!(
        err,
        ProblemError::CalculusMismatch { algorithm: "Backprop", required: Calculus::Ito }
    );
    let err = p.sensitivity_sum(&SensAlg::ForwardPathwise, STEPS).unwrap_err();
    assert_eq!(
        err,
        ProblemError::CalculusMismatch { algorithm: "ForwardPathwise", required: Calculus::Ito }
    );
}

#[test]
fn adaptive_step_control_is_rejected_for_generic_sensitivity() {
    let sde = StratNative;
    let p = prob(&sde);
    let err = p
        .sensitivity_sum(
            &SensAlg::StochasticAdjoint(AdjointConfig::default()),
            StepControl::Adaptive(AdaptiveConfig::default()),
        )
        .unwrap_err();
    assert_eq!(err, ProblemError::AdaptiveSensitivityUnsupported);
}

// ---------------------------------------------------------------------------
// Validation precedes integration: no partial work, errors are pure.
// ---------------------------------------------------------------------------

#[test]
fn validation_errors_are_deterministic_and_cheap() {
    // Calling twice yields the identical error value (nothing stateful
    // ran), and a huge step count costs nothing because the request is
    // rejected up front.
    let sde = ItoNoCorrection;
    let p = prob(&sde);
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let huge = StepControl::Steps(usize::MAX / 2);
    let a = p.sensitivity_sum(&alg, huge).unwrap_err();
    let b = p.sensitivity_sum(&alg, huge).unwrap_err();
    assert_eq!(a, b);
}
