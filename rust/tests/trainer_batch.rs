//! Batched-vs-scalar training engine pins.
//!
//! The batched minibatch ELBO-gradient engine (`elbo_step_batch`) must be
//! **bit-identical** (exact f64 equality) to a sequential per-sequence
//! `elbo_step` loop — for every tested (sequences × samples) shape,
//! including batches that span the engine's internal chunk boundaries,
//! for every worker count, and for both encoder flavors and both
//! diffusion modes. The trainer's resume path must likewise be
//! bit-identical to an uninterrupted run when routed through a
//! `TrainState` checkpoint file.
//!
//! Per-path keys are `keys[m].fold_in(s)`; gradients reduce in path
//! order, so the reference is literally
//! `Σ_{m,s} elbo_step(.., keys[m].fold_in(s), ..).grad`.

use sdegrad::coordinator::{
    load_state, save_state, train_latent_sde, train_latent_sde_from, TrainConfig,
};
use sdegrad::data::gbm::{generate, GbmConfig};
use sdegrad::latent::{
    elbo_step, elbo_step_batch, DiffusionMode, ElboConfig, EncoderKind, LatentSdeConfig,
    LatentSdeModel,
};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;

fn tiny_cfg() -> LatentSdeConfig {
    LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    }
}

fn toy_sequences(n_seqs: usize, n_obs: usize, dx: usize, seed: u64) -> (Vec<f64>, Vec<Vec<f64>>) {
    let times: Vec<f64> = (0..n_obs).map(|k| 0.1 * k as f64).collect();
    let seqs: Vec<Vec<f64>> = (0..n_seqs)
        .map(|m| {
            let mut obs = vec![0.0; n_obs * dx];
            PrngKey::from_seed(seed + m as u64).fill_normal(0, &mut obs);
            for v in obs.iter_mut() {
                *v *= 0.3;
            }
            obs
        })
        .collect();
    (times, seqs)
}

/// The scalar oracle: sequential per-path `elbo_step` calls, gradients
/// summed in path order.
fn scalar_loop(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs_seqs: &[&[f64]],
    keys: &[PrngKey],
    cfg: &ElboConfig,
    n_samples: usize,
) -> (Vec<f64>, f64, f64, Vec<f64>) {
    let mut grad = vec![0.0; model.n_params];
    let (mut loss, mut log_px) = (0.0, 0.0);
    let mut per_path = Vec::new();
    for (m, obs) in obs_seqs.iter().enumerate() {
        for s in 0..n_samples {
            let o = elbo_step(model, params, times, obs, keys[m].fold_in(s as u64), cfg);
            for (g, og) in grad.iter_mut().zip(&o.grad) {
                *g += og;
            }
            loss += o.loss;
            log_px += o.log_px;
            per_path.push(o.loss);
        }
    }
    (grad, loss, log_px, per_path)
}

fn check_exact(model_cfg: LatentSdeConfig, shapes: &[(usize, usize)], seed: u64) {
    let model = LatentSdeModel::new(model_cfg);
    let params = model.init_params(PrngKey::from_seed(seed));
    let cfg = ElboConfig { substeps: 2, kl_weight: 0.7, ..ElboConfig::default() };
    for &(n_seqs, n_samples) in shapes {
        let (times, seqs) = toy_sequences(n_seqs, 4, model.cfg.obs_dim, seed + 100);
        let obs_seqs: Vec<&[f64]> = seqs.iter().map(|s| s.as_slice()).collect();
        let keys: Vec<PrngKey> =
            (0..n_seqs).map(|m| PrngKey::from_seed(seed + 200).fold_in(m as u64)).collect();

        let (grad_ref, loss_ref, logpx_ref, per_path_ref) =
            scalar_loop(&model, &params, &times, &obs_seqs, &keys, &cfg, n_samples);

        let out = elbo_step_batch(&model, &params, &times, &obs_seqs, &keys, &cfg, n_samples, 1);
        assert_eq!(out.n_paths, n_seqs * n_samples);
        assert_eq!(
            out.grad, grad_ref,
            "gradient mismatch at M={n_seqs} S={n_samples}"
        );
        assert_eq!(out.loss, loss_ref, "loss mismatch at M={n_seqs} S={n_samples}");
        assert_eq!(out.log_px, logpx_ref);
        assert_eq!(out.per_path_loss, per_path_ref);
    }
}

/// GRU encoder + learned diffusion (the default model), across shapes
/// that cover single-path, multi-sequence, multi-sample, and batches
/// larger than the engine's 16-path chunk cap (so chunks split mid-batch
/// and mid-sequence).
#[test]
fn batched_matches_scalar_loop_exactly_gru_sde() {
    check_exact(tiny_cfg(), &[(1, 1), (2, 1), (3, 2), (7, 3)], 70);
}

/// First-frames MLP encoder (the mocap protocol).
#[test]
fn batched_matches_scalar_loop_exactly_mlp_encoder() {
    check_exact(
        LatentSdeConfig {
            encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
            ..tiny_cfg()
        },
        &[(1, 2), (4, 2)],
        71,
    );
}

/// Latent-ODE ablation (σ ≡ 0): zero diffusion, zero path-KL, same
/// engine.
#[test]
fn batched_matches_scalar_loop_exactly_ode_mode() {
    check_exact(
        LatentSdeConfig { diffusion: DiffusionMode::Off, ..tiny_cfg() },
        &[(3, 2)],
        72,
    );
}

/// Worker count and the chunk layout it induces must not change a single
/// float: per-path numbers are computed independently and reduced in
/// path order.
#[test]
fn worker_count_does_not_change_floats() {
    let model = LatentSdeModel::new(tiny_cfg());
    let params = model.init_params(PrngKey::from_seed(80));
    let (times, seqs) = toy_sequences(5, 4, 2, 81);
    let obs_seqs: Vec<&[f64]> = seqs.iter().map(|s| s.as_slice()).collect();
    let keys: Vec<PrngKey> =
        (0..5).map(|m| PrngKey::from_seed(82).fold_in(m as u64)).collect();
    let cfg = ElboConfig { substeps: 2, kl_weight: 0.4, ..ElboConfig::default() };

    let base = elbo_step_batch(&model, &params, &times, &obs_seqs, &keys, &cfg, 2, 1);
    for workers in [2, 3, 5, 8] {
        let out = elbo_step_batch(&model, &params, &times, &obs_seqs, &keys, &cfg, 2, workers);
        assert_eq!(out.grad, base.grad, "gradient differs at {workers} workers");
        assert_eq!(out.loss, base.loss, "loss differs at {workers} workers");
        assert_eq!(out.per_path_loss, base.per_path_loss);
    }
}

/// Checkpoint → file → resume must reproduce the uninterrupted run
/// bit-for-bit: the `TrainState` carries the Adam moments and counters,
/// and every schedule is a pure function of the absolute iteration.
#[test]
fn trainer_resume_through_checkpoint_file_is_bit_identical() {
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 1,
        latent_dim: 2,
        context_dim: 1,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 8,
        obs_noise_std: 0.05,
        ..Default::default()
    });
    let ds = generate(
        PrngKey::from_seed(1),
        &GbmConfig { n_series: 8, dt_obs: 0.1, ..Default::default() },
    );
    let idx: Vec<usize> = (0..8).collect();
    let base = TrainConfig {
        iters: 7,
        batch_size: 3,
        lr: 4e-3,
        substeps: 2,
        kl_weight: 0.2,
        kl_anneal_iters: 5,
        exec: ExecConfig::new().threads(2),
        val_every: 0,
        ..Default::default()
    };
    let full = train_latent_sde(&model, &ds, &idx, &[], &base, None);

    let head = train_latent_sde(
        &model,
        &ds,
        &idx,
        &[],
        &TrainConfig { iters: 3, ..base },
        None,
    );
    let path = std::env::temp_dir().join("sdegrad_trainer_batch_resume.bin");
    save_state(&path, &head.final_state).unwrap();
    let restored = load_state(&path).unwrap();
    assert_eq!(restored, head.final_state, "checkpoint roundtrip not exact");

    let tail = train_latent_sde_from(
        &model,
        &ds,
        &idx,
        &[],
        &TrainConfig { iters: 4, ..base },
        None,
        Some(&restored),
    );
    assert_eq!(tail.final_params, full.final_params, "resumed run diverged");
    assert_eq!(tail.final_state.adam_t, full.final_state.adam_t);
    assert_eq!(tail.final_state.iter, full.final_state.iter);
}
