//! Statistical convergence-order suite (the acceptance gate of the
//! convergence subsystem; see `rust/tests/README.md` for how tolerances
//! and seeds were chosen).
//!
//! Every test is deterministic: paths derive from pinned seeds, the
//! bootstrap is keyed, and thread count cannot change any result (the
//! batch API is scheduling-independent). Path counts shrink in debug
//! builds — tier-1 runs this file unoptimized — while the assertions stay
//! identical; CI runs the full scale via
//! `cargo test -q --release --test convergence`.
//!
//! Measured-vs-nominal bands:
//! * strong orders: ±0.15 (the ISSUE's acceptance bound) for the schemes
//!   it names (Euler–Maruyama, Milstein) on GBM/OU; ±0.2 for the
//!   Stratonovich schemes in the constants sweep,
//! * weak orders: [0.6, 1.4] around the nominal 1.0 (first-moment
//!   estimates carry Monte-Carlo noise even with coupled paths),
//! * gradient orders: family-dependent bands, plus the acceptance
//!   criterion that the stochastic adjoint's error decreases *strictly
//!   monotonically* across a ≥4-rung ladder on both GBM and OU.

use sdegrad::adjoint::AdjointConfig;
use sdegrad::api::{SdeProblem, SensAlg};
use sdegrad::convergence::{
    gradient_orders, strong_weak_orders, strong_weak_orders_multi, DtLadder,
};
use sdegrad::prng::PrngKey;
use sdegrad::sde::ou::OrnsteinUhlenbeck;
use sdegrad::sde::problems::Example1;
use sdegrad::sde::ReplicatedSde;
use sdegrad::solvers::Method;

/// Pinned seeds (one stream per test family; paths fold in their index).
const SEED_STRONG_GBM: u64 = 0xC0DE_0001;
const SEED_STRONG_OU: u64 = 0xC0DE_0002;
const SEED_WEAK_GBM: u64 = 0xC0DE_0003;
const SEED_GRAD_GBM: u64 = 0xC0DE_0004;
const SEED_GRAD_OU: u64 = 0xC0DE_0005;
const SEED_CONSTANTS: u64 = 0xC0DE_0006;

const N_BOOT: usize = 300;

/// Debug builds (tier-1 runs unoptimized) use half the paths; the
/// assertions are identical in both profiles, and every band was sized
/// (by simulating the estimator across hundreds of seed realizations)
/// to hold with ≥4σ margin at the *debug* scale.
fn paths(release: usize) -> usize {
    if cfg!(debug_assertions) {
        (release / 2).max(8)
    } else {
        release
    }
}

fn gbm_problem(
    sde: &ReplicatedSde<Example1>,
    seed: u64,
) -> SdeProblem<'_, ReplicatedSde<Example1>> {
    // Moderate coefficients keep the coarse rungs inside the asymptotic
    // regime (large β bends the EM slope upward at coarse h).
    SdeProblem::new(sde, &[1.0, 0.8], (0.0, 1.0))
        .params(&[0.4, 0.5, 0.6, 0.3])
        .key(PrngKey::from_seed(seed))
}

fn ou_problem(ou: &OrnsteinUhlenbeck, seed: u64) -> SdeProblem<'_, OrnsteinUhlenbeck> {
    SdeProblem::new(ou, &[0.9, 0.4], (0.0, 1.0))
        .params(&[1.2, 0.3, 0.5])
        .key(PrngKey::from_seed(seed))
}

// ---------------------------------------------------------------------------
// Strong orders (acceptance: within ±0.15 of nominal on GBM and OU).
// ---------------------------------------------------------------------------

#[test]
fn strong_orders_match_nominal_on_gbm() {
    let sde = ReplicatedSde::new(Example1, 2);
    let prob = gbm_problem(&sde, SEED_STRONG_GBM);
    let ladder = DtLadder::new(32, 5); // h = 1/32 … 1/512
    let n = paths(256);
    let cases = [(Method::EulerMaruyama, 0.5), (Method::MilsteinIto, 1.0)];
    let schemes: Vec<Method> = cases.iter().map(|&(m, _)| m).collect();
    let results = strong_weak_orders_multi(&prob, &schemes, &ladder, n, N_BOOT);
    for (&(method, nominal), res) in cases.iter().zip(&results) {
        assert!(
            (res.strong_fit.order - nominal).abs() <= 0.15,
            "{}: strong order {} (CI [{}, {}]) vs nominal {nominal}; rungs {:?}",
            method.name(),
            res.strong_fit.order,
            res.strong_fit.ci_lo,
            res.strong_fit.ci_hi,
            res.rungs
        );
        // Shared-tree coupling ⇒ the error ladder itself is strictly
        // monotone, not just trending.
        assert!(res.strong_monotone(), "{}: rungs {:?}", method.name(), res.rungs);
    }
}

#[test]
fn strong_orders_match_nominal_on_ou() {
    let ou = OrnsteinUhlenbeck::new(2);
    let prob = ou_problem(&ou, SEED_STRONG_OU);
    let ladder = DtLadder::new(16, 5); // h = 1/16 … 1/256
    let n = paths(192);
    // Additive noise: Euler–Maruyama is strong order 1.0 (the Milstein
    // correction vanishes identically, so MilsteinIto takes the same
    // steps and must measure the same).
    let cases = [(Method::EulerMaruyama, 1.0), (Method::MilsteinIto, 1.0)];
    let schemes: Vec<Method> = cases.iter().map(|&(m, _)| m).collect();
    let results = strong_weak_orders_multi(&prob, &schemes, &ladder, n, N_BOOT);
    for (&(method, nominal), res) in cases.iter().zip(&results) {
        assert!(
            (res.strong_fit.order - nominal).abs() <= 0.15,
            "{}: strong order {} (CI [{}, {}]) vs nominal {nominal}; rungs {:?}",
            method.name(),
            res.strong_fit.order,
            res.strong_fit.ci_lo,
            res.strong_fit.ci_hi,
            res.rungs
        );
        assert!(res.strong_monotone(), "{}: rungs {:?}", method.name(), res.rungs);
    }
}

/// Satellite: the `Method::strong_order()` constants shipped with the
/// solvers must agree with the empirically measured orders — one
/// assertion per method, all methods sharing the same seeded paths. The
/// Stratonovich schemes integrate the converted drift toward the same Itô
/// process, so GBM's closed form is the oracle for all four.
#[test]
fn method_strong_order_constants_agree_with_measurement() {
    let sde = ReplicatedSde::new(Example1, 2);
    let prob = gbm_problem(&sde, SEED_CONSTANTS);
    let ladder = DtLadder::new(32, 5);
    let n = paths(256);
    let schemes = [
        Method::EulerMaruyama,
        Method::MilsteinIto,
        Method::Heun,
        Method::MilsteinStrat,
    ];
    let results = strong_weak_orders_multi(&prob, &schemes, &ladder, n, N_BOOT);
    for (&method, res) in schemes.iter().zip(&results) {
        let nominal = method.strong_order();
        // Predictor-corrector (Heun) and Stratonovich-Milstein carry a
        // slightly wider band: their leading constants are smaller, so
        // the fine rungs sit closer to the Monte-Carlo floor.
        let tol = match method {
            Method::EulerMaruyama | Method::MilsteinIto => 0.15,
            Method::Heun | Method::MilsteinStrat => 0.2,
        };
        assert!(
            (res.strong_fit.order - nominal).abs() <= tol,
            "{}: measured {} (CI [{}, {}]) vs strong_order() {nominal}; rungs {:?}",
            method.name(),
            res.strong_fit.order,
            res.strong_fit.ci_lo,
            res.strong_fit.ci_hi,
            res.rungs
        );
    }
}

// ---------------------------------------------------------------------------
// Weak orders (nominal 1.0 for every scheme here).
// ---------------------------------------------------------------------------

#[test]
fn weak_orders_near_nominal_on_gbm() {
    let sde = ReplicatedSde::new(Example1, 2);
    // Larger drift boosts the first-moment bias (the weak signal) while
    // the path coupling keeps the Monte-Carlo noise at the strong-error
    // scale.
    let prob = SdeProblem::new(&sde, &[1.0, 0.8], (0.0, 1.0))
        .params(&[0.7, 0.4, 0.8, 0.35])
        .key(PrngKey::from_seed(SEED_WEAK_GBM));
    let ladder = DtLadder::new(16, 5); // h = 1/16 … 1/256
    let n = paths(2048);
    for method in [Method::EulerMaruyama, Method::MilsteinIto] {
        let res = strong_weak_orders(&prob, method, &ladder, n, N_BOOT);
        assert!(
            res.weak_fit.order > 0.6 && res.weak_fit.order < 1.4,
            "{}: weak order {} (CI [{}, {}]); rungs {:?}",
            method.name(),
            res.weak_fit.order,
            res.weak_fit.ci_lo,
            res.weak_fit.ci_hi,
            res.rungs
        );
        // The weak error must actually shrink across the ladder ends.
        let (first, last) = (res.rungs.first().unwrap(), res.rungs.last().unwrap());
        assert!(last.weak < first.weak, "{}: rungs {:?}", method.name(), res.rungs);
    }
}

// ---------------------------------------------------------------------------
// Gradient orders (acceptance: stochastic-adjoint error decreases
// strictly monotonically over a ≥4-rung ladder on GBM and OU).
// ---------------------------------------------------------------------------

#[test]
fn adjoint_gradient_error_monotone_and_first_order_on_gbm() {
    let sde = ReplicatedSde::new(Example1, 2);
    let prob = gbm_problem(&sde, SEED_GRAD_GBM);
    let ladder = DtLadder::new(32, 4); // 4 rungs: h = 1/32 … 1/256
    let res = gradient_orders(
        &prob,
        &SensAlg::StochasticAdjoint(AdjointConfig::default()),
        &ladder,
        paths(24),
        N_BOOT,
    )
    .expect("GBM is adjoint-compatible");
    assert!(res.monotone(), "adjoint/GBM not monotone: {:?}", res.rungs);
    assert!(
        (res.fit.order - 1.0).abs() <= 0.3,
        "adjoint/GBM order {} (CI [{}, {}]); rungs {:?}",
        res.fit.order,
        res.fit.ci_lo,
        res.fit.ci_hi,
        res.rungs
    );
}

#[test]
fn adjoint_gradient_error_monotone_and_first_order_on_ou() {
    let ou = OrnsteinUhlenbeck::new(2);
    let prob = ou_problem(&ou, SEED_GRAD_OU);
    let ladder = DtLadder::new(32, 4);
    let res = gradient_orders(
        &prob,
        &SensAlg::StochasticAdjoint(AdjointConfig::default()),
        &ladder,
        paths(24),
        N_BOOT,
    )
    .expect("OU is adjoint-compatible (zero Itô correction)");
    assert!(res.monotone(), "adjoint/OU not monotone: {:?}", res.rungs);
    assert!(
        (res.fit.order - 1.0).abs() <= 0.3,
        "adjoint/OU order {} (CI [{}, {}]); rungs {:?}",
        res.fit.order,
        res.fit.ci_lo,
        res.fit.ci_hi,
        res.rungs
    );
}

/// Every other estimator converges at its own solver's strong order:
/// Milstein-backprop and the antithetic adjoint at ≈1, the
/// Euler-differentiated pair (backprop-Euler ≡ forward pathwise) at ≈½.
/// The taped family realizes independent paths per rung, so only the
/// fitted order is asserted (no monotonicity guarantee), with bands wide
/// enough for the per-rung Monte-Carlo noise.
#[test]
fn gradient_orders_for_all_estimators_on_gbm() {
    let sde = ReplicatedSde::new(Example1, 2);
    let prob = gbm_problem(&sde, SEED_GRAD_GBM);
    // 5 rungs and a fixed 48 paths (no debug scaling — these runs are
    // cheap): the taped family realizes independent paths per rung, so
    // its slope noise is the binding constraint on the bands below.
    let ladder = DtLadder::new(32, 5);
    let n = 48;
    let cases: Vec<(SensAlg, f64, f64)> = vec![
        (SensAlg::Antithetic { base: AdjointConfig::default() }, 0.6, 1.4),
        (SensAlg::backprop(Method::MilsteinIto), 0.6, 1.4),
        (SensAlg::backprop(Method::EulerMaruyama), 0.2, 0.9),
        (SensAlg::ForwardPathwise, 0.2, 0.9),
    ];
    for (alg, lo, hi) in &cases {
        let res = gradient_orders(&prob, alg, &ladder, n, N_BOOT).expect("supported on GBM");
        assert!(
            res.fit.order > *lo && res.fit.order < *hi,
            "{}: order {} outside [{lo}, {hi}] (CI [{}, {}]); rungs {:?}",
            res.alg,
            res.fit.order,
            res.fit.ci_lo,
            res.fit.ci_hi,
            res.rungs
        );
        assert!(res.rungs.iter().all(|r| r.mean_abs_err.is_finite() && r.mean_abs_err > 0.0));
    }
}

/// The taped-path replay also has to work against the quadrature-based OU
/// oracle (exact gradients reconstructed from the replayed stored path).
#[test]
fn backprop_gradient_converges_on_ou() {
    let ou = OrnsteinUhlenbeck::new(2);
    let prob = ou_problem(&ou, SEED_GRAD_OU);
    let ladder = DtLadder::new(32, 4);
    let res = gradient_orders(
        &prob,
        &SensAlg::backprop(Method::MilsteinIto),
        &ladder,
        48, // independent paths per rung: fixed scale, see above
        N_BOOT,
    )
    .expect("OU supports Milstein backprop");
    assert!(
        res.fit.order > 0.6 && res.fit.order < 1.4,
        "backprop/OU order {} (CI [{}, {}]); rungs {:?}",
        res.fit.order,
        res.fit.ci_lo,
        res.fit.ci_hi,
        res.rungs
    );
}

/// Bootstrap sanity on a real measurement: the 95% CI brackets the point
/// estimate and is informative (finite, sub-unit width for a coupled
/// strong ladder).
#[test]
fn bootstrap_confidence_interval_is_informative() {
    let sde = ReplicatedSde::new(Example1, 2);
    let prob = gbm_problem(&sde, SEED_STRONG_GBM);
    let ladder = DtLadder::new(32, 5);
    let res = strong_weak_orders(&prob, Method::MilsteinIto, &ladder, paths(128), N_BOOT);
    let f = res.strong_fit;
    assert!(f.ci_lo.is_finite() && f.ci_hi.is_finite());
    assert!(f.ci_lo <= f.order && f.order <= f.ci_hi, "{f:?}");
    assert!(f.ci_hi - f.ci_lo < 1.0, "uninformative CI: {f:?}");
    assert!(f.n_boot > 0);
}
