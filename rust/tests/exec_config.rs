//! The `ExecConfig` migration contract: every pre-0.2 `_tier` entry
//! point and `tier` builder is a pure delegating shim over the unified
//! `exec`-taking base name, pinned **bit-identical** here — solves,
//! batch sensitivities, ELBO steps, and served response bytes. This is
//! the one file allowed to call the deprecated spellings; everything
//! else in the crate and test suite speaks `ExecConfig`.

#![allow(deprecated)]

use sdegrad::adjoint::AdjointConfig;
use sdegrad::api::{
    sensitivity_batch, sensitivity_batch_tier, solve_batch, SdeProblem, SensAlg,
    SolveOptions, StepControl,
};
use sdegrad::latent::{
    elbo_step_batch, ElboConfig, LatentSdeConfig, LatentSdeModel,
};
use sdegrad::prng::PrngKey;
use sdegrad::runtime::ExecConfig;
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::{KernelTier, ReplicatedSde};
use sdegrad::solvers::Method;
use sdegrad::serve::{client, ModelRegistry, ServeConfig, Server};

/// `ExecConfig`'s builders compose the same value as a struct literal,
/// and the defaults match the pre-0.2 behavior (exact tier, global
/// worker chain, default tree cache).
#[test]
fn exec_config_builders_match_literals() {
    let built = ExecConfig::new().tier(KernelTier::Fast).threads(3);
    let literal = ExecConfig { tier: KernelTier::Fast, threads: Some(3), ..Default::default() };
    assert_eq!(built, literal);
    assert_eq!(ExecConfig::default().tier, KernelTier::Exact);
    assert_eq!(ExecConfig::default().threads, None);
    assert_eq!(built.worker_count(), 3, "explicit threads pin the worker count");
    assert!(ExecConfig::default().worker_count() >= 1);
}

/// `SolveOptions::tier(t)` is exactly `exec.tier = t`: both spellings
/// produce the same options value and the same solve bit stream.
#[test]
fn solve_options_tier_builder_is_bit_identical_to_exec() {
    let dim = 6;
    let gbm = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(91), dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    let replicates = prob.replicates(PrngKey::from_seed(92), 9);
    for tier in [KernelTier::Exact, KernelTier::Fast] {
        let via_tier = SolveOptions::fixed(Method::MilsteinIto, 80).tier(tier);
        let via_exec =
            SolveOptions::fixed(Method::MilsteinIto, 80).exec(ExecConfig::new().tier(tier));
        assert_eq!(via_tier.exec, via_exec.exec);
        let a = solve_batch(&replicates, &via_tier);
        let b = solve_batch(&replicates, &via_exec);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.states, y.states, "tier() vs exec() diverged ({tier:?})");
        }
    }
}

/// The deprecated `sensitivity_batch_tier` shim returns the exact bit
/// stream of `sensitivity_batch` with the equivalent `ExecConfig`.
#[test]
fn sensitivity_batch_tier_shim_is_bit_identical() {
    let dim = 6;
    let gbm = ReplicatedSde::new(Example1, dim);
    let (theta, x0) = sample_experiment_setup(PrngKey::from_seed(93), dim, 2);
    let prob = SdeProblem::new(&gbm, &x0, (0.0, 1.0)).params(&theta);
    let replicates = prob.replicates(PrngKey::from_seed(94), 7);
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let step = StepControl::Steps(60);
    for tier in [KernelTier::Exact, KernelTier::Fast] {
        let old = sensitivity_batch_tier(&replicates, &alg, step, tier);
        let new = sensitivity_batch(&replicates, &alg, step, ExecConfig::new().tier(tier));
        assert_eq!(old.len(), new.len());
        for (o, n) in old.iter().zip(&new) {
            let (o, n) = (o.as_ref().unwrap(), n.as_ref().unwrap());
            assert_eq!(o.dtheta, n.dtheta, "shim dtheta diverged ({tier:?})");
            assert_eq!(o.dz0, n.dz0, "shim dz0 diverged ({tier:?})");
        }
    }
}

/// `ElboConfig::tier(t)` composes the same config as setting `exec`
/// directly, and a full batched ELBO step under either spelling is the
/// same bit stream (this also covers the internal
/// `BatchAdjointOps::new_tier` / `CtxAdjointOps::new_tier` delegation —
/// the ELBO step drives both constructors).
#[test]
fn elbo_config_tier_builder_is_bit_identical_to_exec() {
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(95));
    let times: Vec<f64> = (0..5).map(|k| 0.1 * k as f64).collect();
    let mut obs = vec![0.0; 2 * times.len() * 2];
    PrngKey::from_seed(96).fill_normal(0, &mut obs);
    let rows: Vec<&[f64]> = obs.chunks(times.len() * 2).collect();
    let keys: Vec<PrngKey> = (0..2).map(|m| PrngKey::from_seed(97 + m as u64)).collect();
    for tier in [KernelTier::Exact, KernelTier::Fast] {
        let via_tier = ElboConfig { substeps: 2, kl_weight: 0.6, ..Default::default() }.tier(tier);
        let via_exec = ElboConfig {
            substeps: 2,
            kl_weight: 0.6,
            exec: ExecConfig::new().tier(tier),
        };
        assert_eq!(via_tier.exec, via_exec.exec);
        let a = elbo_step_batch(&model, &params, &times, &rows, &keys, &via_tier, 2, 1);
        let b = elbo_step_batch(&model, &params, &times, &rows, &keys, &via_exec, 2, 1);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged ({tier:?})");
        assert_eq!(a.grad.len(), b.grad.len());
        for (x, y) in a.grad.iter().zip(&b.grad) {
            assert_eq!(x.to_bits(), y.to_bits(), "gradient diverged ({tier:?})");
        }
    }
}

/// A server configured through the delegating `ServeConfig::tier`
/// builder serves the same bytes as one configured through `exec` — the
/// serving half of the migration contract. (The bench-level shim
/// `run_serve_bench_tier` is the same one-line delegation; its
/// signature is pinned here without paying for a full bench run.)
#[test]
fn serve_config_tier_builder_serves_identical_bytes() {
    let _pinned: fn(bool, KernelTier) -> Vec<sdegrad::coordinator::bench::ThroughputRow> =
        sdegrad::coordinator::bench::run_serve_bench_tier;

    let registry = || {
        let model = LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 1,
            latent_dim: 3,
            context_dim: 1,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            obs_noise_std: 0.1,
            ..Default::default()
        });
        let params = model.init_params(PrngKey::from_seed(98));
        let mut reg = ModelRegistry::new();
        reg.insert("default", model, params).unwrap();
        reg
    };
    let body = r#"{"seed": 11, "times": [0, 0.1, 0.2, 0.3], "substeps": 3}"#;

    let mut bodies = Vec::new();
    for via_exec in [false, true] {
        let base = ServeConfig { port: 0, workers: 2, cache_capacity: 0, ..Default::default() };
        let cfg = if via_exec {
            base.exec(ExecConfig::new().tier(KernelTier::Fast))
        } else {
            base.tier(KernelTier::Fast)
        };
        let server = Server::start(registry(), cfg).unwrap();
        let (status, bytes) = client::post(server.addr(), "/v1/simulate", body).unwrap();
        assert_eq!(status, 200);
        bodies.push(bytes);
        server.shutdown();
    }
    assert_eq!(bodies[0], bodies[1], "tier() vs exec() served different bytes");
}
