//! The new problem–solver–solution API must be *bit-identical* to the
//! legacy free functions it replaces: same engines, same Brownian query
//! order, same floats. Every assertion here is `assert_eq!` on f64s — no
//! tolerances. (The legacy names are `#[deprecated]` shims; calling them
//! here is the point.)
#![allow(deprecated)]

use sdegrad::adjoint::{
    adaptive_adjoint_gradients, antithetic_adjoint_gradients, backprop_through_solver,
    forward_pathwise_gradients, stochastic_adjoint_gradients, stochastic_adjoint_multi_obs,
    AdjointConfig, NoiseMode,
};
use sdegrad::api::{
    sensitivity_batch, solve_batch, SaveAt, SdeProblem, SensAlg, SolveOptions, StepControl,
};
use sdegrad::brownian::{BrownianMotion, BrownianPath};
use sdegrad::prng::PrngKey;
use sdegrad::sde::ou::OrnsteinUhlenbeck;
use sdegrad::sde::problems::{sample_experiment_setup, Example1, Example2, Example3};
use sdegrad::sde::{ForwardFunc, ReplicatedSde, ScalarSde};
use sdegrad::solvers::{
    integrate_adaptive, integrate_grid, integrate_grid_saving, uniform_grid, AdaptiveConfig,
    Method,
};

// ---------------------------------------------------------------------------
// Forward solves.
// ---------------------------------------------------------------------------

/// `SdeProblem::solve` with fixed steps + `SaveAt::Final` ==
/// `integrate_grid` over `uniform_grid` on a stored path, bit for bit.
#[test]
fn solve_final_matches_integrate_grid() {
    let cases = [
        (1usize, 11u64, Method::EulerMaruyama),
        (4, 12, Method::MilsteinIto),
        (3, 13, Method::Heun),
    ];
    for (dim, seed, method) in cases {
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let n = 257;

        let mut bm = BrownianPath::new(key, dim, 0.0, 1.0);
        let grid = uniform_grid(0.0, 1.0, n);
        let mut sys = ForwardFunc::for_method(&sde, &theta, method);
        let mut y_legacy = vec![0.0; dim];
        let stats_legacy = integrate_grid(&mut sys, method, &x0, &grid, &mut bm, &mut y_legacy);

        let sol = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .key(key)
            .solve(&SolveOptions::fixed(method, n));

        assert_eq!(sol.final_state(), &y_legacy[..], "method {}", method.name());
        assert_eq!(sol.stats, stats_legacy);
    }
}

/// `SaveAt::Dense` == `integrate_grid_saving`, including on OU.
#[test]
fn solve_dense_matches_integrate_grid_saving() {
    let ou = OrnsteinUhlenbeck::new(3);
    let theta = [1.2, 0.4, 0.6];
    let z0 = [0.1, -0.3, 0.8];
    let key = PrngKey::from_seed(21);
    let n = 128;

    let mut bm = BrownianPath::new(key, 3, 0.0, 2.0);
    let grid = uniform_grid(0.0, 2.0, n);
    let mut sys = ForwardFunc::for_method(&ou, &theta, Method::Heun);
    let (traj, _) = integrate_grid_saving(&mut sys, Method::Heun, &z0, &grid, &mut bm);

    let sol = SdeProblem::new(&ou, &z0, (0.0, 2.0))
        .params(&theta)
        .key(key)
        .solve(&SolveOptions::fixed(Method::Heun, n).save(SaveAt::Dense));

    assert_eq!(sol.states, traj);
    assert_eq!(sol.times, grid);
    // Interpolation is exact at saved points and the replay handle
    // reveals the same path the legacy bm realized.
    let mut sol = sol;
    assert_eq!(sol.at(grid[17]), sol.state(17).to_vec());
    assert_eq!(sol.w(2.0), bm.sample(2.0));
}

/// `StepControl::Adaptive` == `integrate_adaptive`.
#[test]
fn solve_adaptive_matches_integrate_adaptive() {
    let sde = ReplicatedSde::new(Example2, 2);
    let key = PrngKey::from_seed(31);
    let (theta, x0) = sample_experiment_setup(key, 2, 1);
    let cfg = AdaptiveConfig { atol: 1e-4, rtol: 0.0, ..Default::default() };

    let mut bm = BrownianPath::new(key, 2, 0.0, 1.0);
    let mut sys = ForwardFunc::for_method(&sde, &theta, Method::MilsteinIto);
    let legacy = integrate_adaptive(&mut sys, Method::MilsteinIto, &x0, 0.0, 1.0, &mut bm, &cfg);

    let sol = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .solve(&SolveOptions::adaptive(Method::MilsteinIto, cfg));

    assert_eq!(sol.final_state(), &legacy.y[..]);
    assert_eq!(sol.stats, legacy.stats);
    assert_eq!(sol.hit_h_min, legacy.hit_h_min);
}

// ---------------------------------------------------------------------------
// Sensitivity algorithms, on all three §7.1 problems.
// ---------------------------------------------------------------------------

fn check_sensitivity_equivalence<P: ScalarSde + Copy>(problem: P, dim: usize, seed: u64) {
    let sde = ReplicatedSde::new(problem, dim);
    let key = PrngKey::from_seed(seed);
    let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
    let n = 400;
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
    let step = StepControl::Steps(n);

    // Stochastic adjoint, stored path.
    let cfg = AdjointConfig::default();
    let legacy = stochastic_adjoint_gradients(&sde, &theta, &x0, 0.0, 1.0, n, key, &cfg);
    let new = prob.sensitivity_sum(&SensAlg::StochasticAdjoint(cfg), step).unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta, "{}: adjoint dtheta", problem.name());
    assert_eq!(new.dz0, legacy.grad_z0, "{}: adjoint dz0", problem.name());
    assert_eq!(new.z_terminal, legacy.z_terminal);
    assert_eq!(new.z0_reconstructed, legacy.z0_reconstructed);
    assert_eq!(new.w_terminal, legacy.w_terminal);
    assert_eq!(new.stats.forward, legacy.forward_stats);
    assert_eq!(new.stats.backward, legacy.backward_stats);
    assert_eq!(new.stats.noise_memory, legacy.noise_memory);

    // Stochastic adjoint, virtual tree (problem-level noise spec must
    // reproduce the config-level one).
    let tree_cfg = AdjointConfig { noise: NoiseMode::VirtualTree { tol: 1e-6 }, ..cfg };
    let legacy = stochastic_adjoint_gradients(&sde, &theta, &x0, 0.0, 1.0, n, key, &tree_cfg);
    let new = prob
        .clone()
        .noise(NoiseMode::VirtualTree { tol: 1e-6 })
        .sensitivity_sum(&SensAlg::StochasticAdjoint(cfg), step)
        .unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta, "{}: tree adjoint", problem.name());

    // Backprop through the solver, both schemes.
    for method in [Method::EulerMaruyama, Method::MilsteinIto] {
        let legacy = backprop_through_solver(&sde, &theta, &x0, 0.0, 1.0, n, key, method);
        let new = prob.sensitivity_sum(&SensAlg::Backprop { method }, step).unwrap();
        assert_eq!(new.dtheta, legacy.grad_theta, "{}: backprop {}", problem.name(), method.name());
        assert_eq!(new.dz0, legacy.grad_z0);
        assert_eq!(new.stats.noise_memory, legacy.noise_memory);
    }

    // Forward pathwise.
    let legacy = forward_pathwise_gradients(&sde, &theta, &x0, 0.0, 1.0, n, key);
    let new = prob.sensitivity_sum(&SensAlg::ForwardPathwise, step).unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta, "{}: pathwise", problem.name());
    assert_eq!(new.dz0, legacy.grad_z0);

    // Antithetic pair.
    let legacy = antithetic_adjoint_gradients(&sde, &theta, &x0, 0.0, 1.0, n, key, &cfg);
    let new = prob.sensitivity_sum(&SensAlg::Antithetic { base: cfg }, step).unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta, "{}: antithetic", problem.name());
    assert_eq!(new.dz0, legacy.grad_z0);

    // Adaptive adjoint (replicated scalar problems only).
    let acfg = AdaptiveConfig { atol: 1e-3, rtol: 0.0, h0: 1e-3, ..Default::default() };
    let legacy = adaptive_adjoint_gradients(&sde, &theta, &x0, 0.0, 1.0, key, &acfg);
    let new = prob.sensitivity_adaptive(&acfg);
    assert_eq!(new.dtheta, legacy.grad_theta, "{}: adaptive adjoint", problem.name());
    assert_eq!(new.dz0, legacy.grad_z0);
    assert_eq!(new.stats.hit_h_min, legacy.hit_h_min);
}

#[test]
fn sensitivity_matches_legacy_example1_gbm() {
    check_sensitivity_equivalence(Example1, 3, 101);
}

#[test]
fn sensitivity_matches_legacy_example2() {
    check_sensitivity_equivalence(Example2, 2, 102);
}

#[test]
fn sensitivity_matches_legacy_example3() {
    check_sensitivity_equivalence(Example3, 4, 103);
}

/// The adjoint on OU (Itô-native, additive noise) — the system whose
/// missing correction VJP used to panic at runtime; now it is implemented
/// (identically zero) and validated at problem construction.
#[test]
fn sensitivity_matches_legacy_on_ou() {
    let ou = OrnsteinUhlenbeck::new(2);
    let theta = [1.5, 0.7, 0.3];
    let z0 = [0.4, -0.2];
    let key = PrngKey::from_seed(41);
    let n = 300;
    let cfg = AdjointConfig::default();

    let legacy = stochastic_adjoint_gradients(&ou, &theta, &z0, 0.0, 1.0, n, key, &cfg);
    let new = SdeProblem::new(&ou, &z0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .sensitivity_sum(&SensAlg::StochasticAdjoint(cfg), StepControl::Steps(n))
        .unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta);
    assert_eq!(new.dz0, legacy.grad_z0);
}

/// Multi-observation adjoint == `stochastic_adjoint_multi_obs`.
#[test]
fn sensitivity_at_matches_legacy_multi_obs() {
    let sde = ReplicatedSde::new(Example3, 2);
    let key = PrngKey::from_seed(51);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let cfg = AdjointConfig::default();
    let obs = [0.25, 0.5, 1.0];

    let legacy = stochastic_adjoint_multi_obs(&sde, &theta, &x0, 0.0, &obs, 120, key, &cfg, |z| {
        vec![1.0; z.len()]
    });
    let new = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .sensitivity_at(&obs, 120, &cfg, |z| vec![1.0; z.len()])
        .unwrap();
    assert_eq!(new.dtheta, legacy.grad_theta);
    assert_eq!(new.dz0, legacy.grad_z0);
    assert_eq!(new.z_terminal, legacy.z_terminal);
}

// ---------------------------------------------------------------------------
// Validation surfaces errors where the legacy path panicked.
// ---------------------------------------------------------------------------

/// An Itô-native SDE without the correction VJP is rejected at
/// validation, not mid-solve.
#[test]
fn missing_correction_vjp_is_an_error_not_a_panic() {
    use sdegrad::api::ProblemError;
    use sdegrad::sde::{Calculus, Sde, SdeVjp};

    struct NoCorrection;
    impl Sde for NoCorrection {
        fn state_dim(&self) -> usize {
            1
        }
        fn param_dim(&self) -> usize {
            1
        }
        fn calculus(&self) -> Calculus {
            Calculus::Ito
        }
        fn drift(&self, _t: f64, z: &[f64], th: &[f64], out: &mut [f64]) {
            out[0] = th[0] * z[0];
        }
        fn diffusion(&self, _t: f64, z: &[f64], _th: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * z[0];
        }
        fn diffusion_dz_diag(&self, _t: f64, _z: &[f64], _th: &[f64], out: &mut [f64]) {
            out[0] = 0.5;
        }
    }
    impl SdeVjp for NoCorrection {
        fn drift_vjp(
            &self,
            _t: f64,
            z: &[f64],
            _th: &[f64],
            a: &[f64],
            out_z: &mut [f64],
            out_theta: &mut [f64],
        ) {
            out_z[0] += a[0];
            out_theta[0] += a[0] * z[0];
        }
        fn diffusion_vjp(
            &self,
            _t: f64,
            _z: &[f64],
            _th: &[f64],
            a: &[f64],
            out_z: &mut [f64],
            _out_theta: &mut [f64],
        ) {
            out_z[0] += 0.5 * a[0];
        }
        // has_ito_correction_vjp stays false.
    }

    let prob = SdeProblem::new(&NoCorrection, &[1.0], (0.0, 1.0)).params(&[0.3]);
    let err = prob
        .sensitivity_sum(
            &SensAlg::StochasticAdjoint(AdjointConfig::default()),
            StepControl::Steps(10),
        )
        .unwrap_err();
    assert!(matches!(err, ProblemError::MissingItoCorrectionVjp { .. }), "{err}");
    // Backprop-Milstein needs it too; Euler does not.
    let err = prob
        .sensitivity_sum(&SensAlg::Backprop { method: Method::MilsteinIto }, StepControl::Steps(10))
        .unwrap_err();
    assert!(matches!(err, ProblemError::MissingItoCorrectionVjp { .. }), "{err}");
    prob.sensitivity_sum(
        &SensAlg::Backprop { method: Method::EulerMaruyama },
        StepControl::Steps(10),
    )
    .expect("Euler backprop needs no correction VJP");
}

/// Backprop/pathwise tape their own stored path; a virtual-tree or
/// mirrored problem spec must be rejected rather than silently realizing
/// a different path from the same key.
#[test]
fn taping_estimators_reject_non_default_noise() {
    use sdegrad::api::ProblemError;

    let sde = ReplicatedSde::new(Example1, 2);
    let key = PrngKey::from_seed(81);
    let (theta, x0) = sample_experiment_setup(key, 2, 2);
    let step = StepControl::Steps(50);
    let tree = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .noise(NoiseMode::VirtualTree { tol: 1e-6 });
    let mirrored = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key).mirror(true);

    for prob in [&tree, &mirrored] {
        for alg in
            [SensAlg::Backprop { method: Method::EulerMaruyama }, SensAlg::ForwardPathwise]
        {
            let err = prob.sensitivity_sum(&alg, step).unwrap_err();
            assert!(matches!(err, ProblemError::UnsupportedNoise { .. }), "{err}");
        }
        // The adjoint family honors the same specs.
        prob.sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), step)
            .expect("adjoint honors tree/mirror specs");
    }
}

// ---------------------------------------------------------------------------
// Batch determinism.
// ---------------------------------------------------------------------------

/// `solve_batch` output is identical to sequential solving (thread count
/// can only affect scheduling, never results), and replicates with
/// distinct keys realize distinct paths.
#[test]
fn solve_batch_is_deterministic_and_order_preserving() {
    let sde = ReplicatedSde::new(Example1, 3);
    let key = PrngKey::from_seed(61);
    let (theta, x0) = sample_experiment_setup(key, 3, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let opts = SolveOptions::fixed(Method::MilsteinIto, 200);
    let root = PrngKey::from_seed(62);

    let replicates = prob.replicates(root, 17);
    let batch_a = solve_batch(&replicates, &opts);
    let batch_b = solve_batch(&replicates, &opts);
    let sequential: Vec<_> = replicates.iter().map(|p| p.solve(&opts)).collect();

    assert_eq!(batch_a.len(), 17);
    for i in 0..17 {
        assert_eq!(batch_a[i].states, batch_b[i].states, "run-to-run at {i}");
        assert_eq!(batch_a[i].states, sequential[i].states, "batch vs sequential at {i}");
    }
    assert_ne!(batch_a[0].states, batch_a[1].states, "replicates must differ");
}

/// Same for gradient batches.
#[test]
fn sensitivity_batch_matches_sequential() {
    let sde = ReplicatedSde::new(Example2, 2);
    let key = PrngKey::from_seed(71);
    let (theta, x0) = sample_experiment_setup(key, 2, 1);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let step = StepControl::Steps(150);

    let replicates = prob.replicates(PrngKey::from_seed(72), 9);
    let batch = sensitivity_batch(&replicates, &alg, step);
    for (i, p) in replicates.iter().enumerate() {
        let seq = p.sensitivity_sum(&alg, step).unwrap();
        let b = batch[i].as_ref().unwrap();
        assert_eq!(b.dtheta, seq.dtheta, "batch vs sequential at {i}");
        assert_eq!(b.dz0, seq.dz0);
    }
}
