//! The observability layer's contract, end to end.
//!
//! The load-bearing pin: **instrumentation never moves a bit**. Spans
//! and registry metrics are integer-only, so batched solves, checkpointed
//! gradients, and ELBO training steps are bit-identical with span
//! collection off (the default) and on. On top of that: the Chrome-trace
//! exporter emits strict JSON (parsed back through the crate's own
//! `metrics::json::parse_json`) whose begin/end events are well-nested
//! per thread; registry counters are monotone under concurrent updates;
//! and the power-of-two histogram bucket boundaries are pinned so
//! exported bucket counts stay comparable across builds.
//!
//! Span collection is a process-wide flag, so the tests that toggle it
//! serialize on a local mutex; none of them asserts exact event or
//! counter totals (other engine calls in the process legitimately feed
//! the same registry).

use std::collections::HashMap;
use std::sync::Mutex;

use sdegrad::api::{
    solve_batch, Checkpointing, NoiseSpec, SdeProblem, SensAlg, SolveOptions, StepControl,
};
use sdegrad::latent::{elbo_step_batch, ElboConfig, LatentSdeConfig, LatentSdeModel};
use sdegrad::metrics::json::{parse_json, JsonValue};
use sdegrad::obs;
use sdegrad::prng::PrngKey;
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::ReplicatedSde;
use sdegrad::solvers::Method;

/// Serializes the tests that toggle the process-wide span flag or drain
/// the global event sink.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn assert_same_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

struct Workload {
    solve_states: Vec<f64>,
    dtheta: Vec<f64>,
    dz0: Vec<f64>,
    z_terminal: Vec<f64>,
    elbo_loss: f64,
    elbo_grad: Vec<f64>,
}

/// One pass over every instrumented layer: a batched solve (solver step
/// loop + workspace recycling), a checkpointed virtual-tree gradient
/// (forward / replay / backward spans, peak-tape and recompute gauges,
/// bridge-call and tree-cache counters), and a batched ELBO step
/// (encoder / posterior-solve / decoder / BPTT phases on the pool).
fn run_workload() -> Workload {
    let dim = 4;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(9100);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);

    let replicates = prob.replicates(PrngKey::from_seed(9101), 7);
    let solved = solve_batch(&replicates, &SolveOptions::fixed(Method::MilsteinIto, 48));
    let solve_states: Vec<f64> = solved.iter().flat_map(|s| s.states.iter().copied()).collect();

    let g = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(PrngKey::from_seed(9102))
        .noise(NoiseSpec::VirtualTree { tol: 1e-8 })
        .sensitivity_sum(
            &SensAlg::Backprop {
                method: Method::MilsteinIto,
                checkpointing: Checkpointing::Sqrt,
            },
            StepControl::Steps(64),
        )
        .unwrap();

    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 2,
        latent_dim: 3,
        context_dim: 2,
        hidden: 8,
        diff_hidden: 4,
        enc_hidden: 6,
        obs_noise_std: 0.1,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(9103));
    let times: Vec<f64> = (0..5).map(|k| 0.1 * k as f64).collect();
    let n_seq = 3;
    let mut obs_data = vec![0.0; n_seq * times.len() * 2];
    PrngKey::from_seed(9104).fill_normal(0, &mut obs_data);
    let rows: Vec<&[f64]> = obs_data.chunks(times.len() * 2).collect();
    let keys: Vec<PrngKey> = (0..n_seq).map(|m| PrngKey::from_seed(9110 + m as u64)).collect();
    let cfg = ElboConfig { substeps: 2, kl_weight: 0.4, ..Default::default() };
    let out = elbo_step_batch(&model, &params, &times, &rows, &keys, &cfg, 2, 2);

    Workload {
        solve_states,
        dtheta: g.dtheta,
        dz0: g.dz0,
        z_terminal: g.z_terminal,
        elbo_loss: out.loss,
        elbo_grad: out.grad,
    }
}

/// Begin/end events must form a well-nested bracket sequence per thread
/// id, with matching names — the property that makes the Chrome trace
/// render as a clean flame graph.
fn assert_well_nested(events: &[obs::Event]) {
    let mut stacks: HashMap<u64, Vec<&'static str>> = HashMap::new();
    for ev in events {
        let stack = stacks.entry(ev.tid).or_default();
        if ev.begin {
            stack.push(ev.name);
        } else {
            let open = stack
                .pop()
                .unwrap_or_else(|| panic!("end `{}` without begin on tid {}", ev.name, ev.tid));
            assert_eq!(open, ev.name, "mismatched nesting on tid {}", ev.tid);
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "unclosed spans on tid {tid}: {stack:?}");
    }
}

/// THE determinism pin: solve states, checkpointed gradients, and ELBO
/// losses/gradients are bit-identical with span collection off and on.
#[test]
fn tracing_on_and_off_is_bit_identical_across_every_layer() {
    let _guard = obs_lock();
    obs::set_enabled(false);
    let off = run_workload();
    obs::set_enabled(true);
    let on = run_workload();
    obs::set_enabled(false);
    obs::clear_events();

    assert_same_bits(&off.solve_states, &on.solve_states, "batched solve states");
    assert_same_bits(&off.dtheta, &on.dtheta, "checkpointed dtheta");
    assert_same_bits(&off.dz0, &on.dz0, "checkpointed dz0");
    assert_same_bits(&off.z_terminal, &on.z_terminal, "checkpointed z_terminal");
    assert_same_bits(&[off.elbo_loss], &[on.elbo_loss], "elbo loss");
    assert_same_bits(&off.elbo_grad, &on.elbo_grad, "elbo gradient");
}

/// An enabled run produces spans from every instrumented layer, drains
/// to a well-nested per-thread event stream, and exports Chrome
/// trace-event JSON that parses under the crate's strict grammar with
/// one trace event per drained span event.
#[test]
fn chrome_trace_is_strict_json_with_well_nested_spans() {
    let _guard = obs_lock();
    obs::set_enabled(true);
    obs::clear_events();
    let _ = run_workload();
    obs::set_enabled(false);
    let events = obs::drain_events();

    assert!(!events.is_empty(), "an enabled run must record spans");
    assert_well_nested(&events);
    for prefix in ["solve.batch.", "ckpt.", "elbo."] {
        assert!(
            events.iter().any(|e| e.name.starts_with(prefix)),
            "no `{prefix}*` span recorded; got {:?}",
            events.iter().map(|e| e.name).collect::<std::collections::BTreeSet<_>>()
        );
    }

    let trace = obs::export::chrome_trace_from(&events);
    let doc = parse_json(&trace).expect("Chrome trace must be strict JSON");
    let list = doc.get("traceEvents").expect("traceEvents key").as_array().expect("array");
    assert_eq!(list.len(), events.len(), "one trace event per span event");
    for (ev, json) in events.iter().zip(list) {
        let ph = match json.get("ph") {
            Some(JsonValue::Str(s)) => s.clone(),
            other => panic!("ph must be a string, got {other:?}"),
        };
        assert_eq!(ph, if ev.begin { "B" } else { "E" });
        assert_eq!(json.get("name"), Some(&JsonValue::Str(ev.name.to_string())));
        assert_eq!(json.get("ts").and_then(|v| v.as_u64()), Some(ev.ts_us));
        assert_eq!(json.get("tid").and_then(|v| v.as_u64()), Some(ev.tid));
    }
}

/// The instrumented engines feed the always-on registry: the workload
/// bumps the Brownian bridge-call counter (through the
/// `metrics::counters` shim and the registry handle in lockstep) and
/// publishes the checkpoint-schedule gauges.
#[test]
fn engine_runs_feed_the_registry() {
    let _guard = obs_lock();
    let before = obs::counter("brownian.bridge_calls").get();
    let _ = run_workload();
    let after = obs::counter("brownian.bridge_calls").get();
    assert!(after > before, "virtual-tree gradient must draw bridges ({before} -> {after})");
    assert_eq!(
        after,
        sdegrad::metrics::counters::bridge_calls_total(),
        "the legacy shim and the registry counter are the same atomic"
    );
    let snap: HashMap<&'static str, obs::MetricValue> = obs::snapshot().into_iter().collect();
    assert!(
        matches!(snap.get("adjoint.peak_tape_bytes"), Some(obs::MetricValue::Gauge(v)) if *v > 0),
        "checkpointed run must publish its peak tape gauge; got {:?}",
        snap.get("adjoint.peak_tape_bytes")
    );
}

/// Counters stay exact (no lost updates) under concurrent writers, and
/// every handle for a name shares one atomic.
#[test]
fn registry_counters_are_monotone_under_concurrent_updates() {
    let c = obs::counter("test.obs.concurrent");
    let before = c.get();
    let threads = 8;
    let per_thread = 10_000u64;
    std::thread::scope(|s| {
        for _ in 0..threads {
            let c = c.clone();
            s.spawn(move || {
                for _ in 0..per_thread {
                    c.inc();
                }
            });
        }
    });
    assert_eq!(
        obs::counter("test.obs.concurrent").get() - before,
        threads as u64 * per_thread
    );
}

/// Registering one name as two different metric kinds is a bug, caught
/// loudly.
#[test]
#[should_panic(expected = "already registered with a different kind")]
fn metric_kind_clash_panics() {
    let _ = obs::counter("test.obs.kind_clash");
    let _ = obs::gauge("test.obs.kind_clash");
}

/// The power-of-two bucket boundaries, pinned: bucket 0 holds exactly 0,
/// bucket i holds [2^(i-1), 2^i), the top bucket is open-ended. Exported
/// bucket counts (serve `/metrics`, `dump_json`) rely on this mapping
/// staying fixed.
#[test]
fn histogram_bucket_boundaries_are_pinned() {
    assert_eq!(obs::BUCKETS, 64);
    for (value, bucket) in [
        (0u64, 0usize),
        (1, 1),
        (2, 2),
        (3, 2),
        (4, 3),
        (7, 3),
        (8, 4),
        (1023, 10),
        (1024, 11),
        (u64::MAX, 63),
    ] {
        assert_eq!(obs::bucket_index(value), bucket, "bucket_index({value})");
    }
    for i in 1..obs::BUCKETS {
        assert_eq!(obs::bucket_lower_bound(i), 1u64 << (i - 1));
        assert_eq!(obs::bucket_index(obs::bucket_lower_bound(i)), i);
    }
    let h = obs::Hist::new();
    h.record(0);
    h.record(1000);
    h.record(1000);
    let counts = h.counts();
    assert_eq!((counts[0], counts[10], h.total()), (1, 2, 3));
}

/// `dump_json` (the `/metrics` `"registry"` payload) is strict JSON with
/// the three kind maps, and reflects the live values.
#[test]
fn registry_dump_is_strict_json() {
    obs::counter("test.obs.dump").add(3);
    obs::gauge("test.obs.dump_gauge").set(17);
    obs::hist("test.obs.dump_hist").record(5);
    let doc = parse_json(&obs::dump_json()).expect("dump_json must be strict JSON");
    let counter = doc
        .get("counters")
        .and_then(|c| c.get("test.obs.dump"))
        .and_then(|v| v.as_u64())
        .expect("counter present");
    assert!(counter >= 3, "counter at least what we added, got {counter}");
    assert_eq!(
        doc.get("gauges").and_then(|g| g.get("test.obs.dump_gauge")).and_then(|v| v.as_u64()),
        Some(17)
    );
    let buckets = doc
        .get("histograms")
        .and_then(|h| h.get("test.obs.dump_hist"))
        .and_then(|v| v.as_array())
        .expect("histogram present");
    // 5 lands in bucket 3 ([4, 8)); trailing zeros are trimmed.
    assert_eq!(buckets.len(), 4);
    assert!(buckets[3].as_u64().unwrap() >= 1);
}
