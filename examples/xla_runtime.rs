//! The AOT bridge end-to-end: load the JAX/Pallas-lowered artifacts and
//! run them from Rust via PJRT, cross-checking numerics and comparing
//! throughput against the pure-Rust NN path.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_runtime
//! ```

use sdegrad::latent::{LatentSdeConfig, LatentSdeModel};
use sdegrad::metrics::timer::bench;
use sdegrad::prng::PrngKey;
use sdegrad::ensure;
use sdegrad::error::Result;
use sdegrad::runtime::ArtifactRegistry;

fn main() -> Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    let m = &reg.manifest;
    println!("loaded manifest: {} entries, n_params = {}", m.entries.len(), m.cfg["n_params"]);

    // Reconstruct the exact model config the artifacts were built for.
    let cfg = LatentSdeConfig {
        obs_dim: m.cfg_usize("obs_dim")?,
        latent_dim: m.cfg_usize("latent_dim")?,
        context_dim: m.cfg_usize("context_dim")?,
        hidden: m.cfg_usize("hidden")?,
        diff_hidden: m.cfg_usize("diff_hidden")?,
        enc_hidden: m.cfg_usize("enc_hidden")?,
        ..Default::default()
    };
    let batch = m.cfg_usize("batch")?;
    let model = LatentSdeModel::new(cfg);
    ensure!(
        model.n_params == m.cfg_usize("n_params")?,
        "Rust/Python layout mismatch"
    );

    // Shared inputs.
    let params = model.init_params(PrngKey::from_seed(1));
    let params_f32: Vec<f32> = params.iter().map(|&v| v as f32).collect();
    let d_in = cfg.latent_dim + 1 + cfg.context_dim;
    let mut zin = vec![0.0f64; batch * d_in];
    PrngKey::from_seed(2).fill_normal(0, &mut zin);
    let zin_f32: Vec<f32> = zin.iter().map(|&v| v as f32).collect();

    // Numerics cross-check.
    let exe = reg.get("post_drift_fwd")?;
    let out = exe.call_f32(&[&params_f32, &zin_f32])?;
    let mut cache = model.post_drift.cache();
    let mut max_err = 0.0f64;
    for b in 0..batch {
        let mut want = vec![0.0f64; cfg.latent_dim];
        model.post_drift.forward(&params, &zin[b * d_in..(b + 1) * d_in], &mut cache, &mut want);
        for i in 0..cfg.latent_dim {
            max_err = max_err.max((out[0][b * cfg.latent_dim + i] as f64 - want[i]).abs());
        }
    }
    println!("XLA vs Rust-NN posterior drift: max |Δ| = {max_err:.2e} over {batch}×{} outputs", cfg.latent_dim);
    ensure!(max_err < 1e-4, "numerics mismatch");

    // Throughput: batched XLA artifact vs per-row Rust NN.
    let stats_xla = bench(3, 30, || {
        let out = exe.call_f32(&[&params_f32, &zin_f32]).unwrap();
        out[0][0] as f64
    });
    let mut sink = vec![0.0f64; cfg.latent_dim];
    let stats_rust = bench(3, 30, || {
        let mut acc = 0.0;
        for b in 0..batch {
            model.post_drift.forward(&params, &zin[b * d_in..(b + 1) * d_in], &mut cache, &mut sink);
            acc += sink[0];
        }
        acc
    });
    println!(
        "drift eval, batch {batch}: XLA artifact {:.1} µs/call, Rust NN {:.1} µs/batch",
        stats_xla.mean() * 1e6,
        stats_rust.mean() * 1e6
    );

    // Fused Euler step artifact (the training hot step).
    let dz = cfg.latent_dim;
    let step = reg.get("elbo_euler_step")?;
    let z = vec![0.1f32; batch * dz];
    let l = vec![0.0f32; batch];
    let t = [0.0f32];
    let dt = [0.01f32];
    let ctx = vec![0.0f32; batch * cfg.context_dim];
    let dw = vec![0.01f32; batch * dz];
    let outs = step.call_f32(&[&params_f32, &z, &l, &t[..1], &dt[..1], &ctx, &dw])?;
    println!(
        "elbo_euler_step: z' {} values, ℓ' {} values — OK",
        outs[0].len(),
        outs[1].len()
    );
    let stats_step = bench(3, 30, || {
        let o = step.call_f32(&[&params_f32, &z, &l, &t[..1], &dt[..1], &ctx, &dw]).unwrap();
        o[1][0] as f64
    });
    println!("fused step: {:.1} µs/call (batch {batch})", stats_step.mean() * 1e6);
    println!("xla_runtime OK");
    Ok(())
}
