//! Quickstart: the problem → solve → sensitivity API in ~15 lines, then a
//! small parameter-calibration loop driven by the stochastic adjoint.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 defines one [`SdeProblem`] (10-d replicated geometric Brownian
//! motion) and computes `∂(Σ X_T)/∂θ` with three interchangeable
//! estimators — the stochastic adjoint (this paper), backprop through the
//! solver, and the analytic pathwise gradient — showing they agree while
//! the adjoint keeps O(1) solver state with a virtual Brownian tree.
//!
//! Part 2 calibrates GBM parameters by pathwise stochastic optimization:
//! minimize `E[(X_T − X*_T)²]` against a ground-truth model on the *same*
//! Brownian paths, with gradients from the adjoint. Because the adjoint is
//! linear in the terminal loss-gradient, one ones-vector backward pass per
//! path is rescaled by the residual.
//!
//! Part 3 is batched Monte Carlo: one `solve_batch` /
//! `sensitivity_batch` call fans thousands of replicates through the
//! batched SoA engine (chunks of paths advance together per solver step)
//! and reduces them to `E[X_T]` and `∂E[Σ X_T]/∂θ` estimates — results
//! bit-identical to a per-path loop, at batched-engine throughput.

use sdegrad::api::solve_batch_per_path;
use sdegrad::optim::Adam;
use sdegrad::prelude::*;
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::ScalarSde;

fn main() {
    part1_gradient_agreement();
    part2_calibration();
    part3_batched_monte_carlo();
}

fn part1_gradient_agreement() {
    println!("── Part 1: one problem, three gradient estimators (10-d GBM) ──");
    let dim = 10;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(0);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let step = StepControl::Steps(2000);

    // The whole API in one chain: problem → solve → sensitivity.
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
    let sol = prob.solve(&SolveOptions::fixed(Method::MilsteinIto, 2000));
    let adj = prob
        .sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), step)
        .expect("adjoint-compatible problem");
    let bp = prob
        .sensitivity_sum(&SensAlg::Backprop { method: Method::MilsteinIto }, step)
        .expect("backprop-compatible problem");
    println!("forward solve: z_T[0] = {:.6} in {} steps", sol.final_state()[0], sol.stats.steps);

    let mut g_x0 = vec![0.0; dim];
    let mut g_th = vec![0.0; theta.len()];
    sde.analytic_loss_gradients(1.0, &x0, &theta, &adj.w_terminal, &mut g_x0, &mut g_th);

    println!("{:>6} {:>14} {:>14} {:>14}", "θ[j]", "adjoint", "backprop", "analytic");
    for j in (0..theta.len()).step_by(5) {
        println!("{:>6} {:>14.6} {:>14.6} {:>14.6}", j, adj.dtheta[j], bp.dtheta[j], g_th[j]);
    }
    let max_rel = g_th
        .iter()
        .zip(&adj.dtheta)
        .map(|(a, b)| (a - b).abs() / a.abs().max(1e-3))
        .fold(0.0f64, f64::max);
    println!("max relative adjoint-vs-analytic error: {max_rel:.2e}");
    println!(
        "noise memory — adjoint stored-path: {} floats; backprop tape: {} floats",
        adj.stats.noise_memory, bp.stats.noise_memory
    );

    // Same problem, O(1)-memory noise: one builder call, nothing else
    // changes.
    let tree = prob
        .clone()
        .noise(NoiseSpec::VirtualTree { tol: 1e-6 })
        .sensitivity_sum(&SensAlg::StochasticAdjoint(AdjointConfig::default()), step)
        .expect("adjoint-compatible problem");
    println!(
        "                — adjoint virtual-tree: {} floats (O(1))\n",
        tree.stats.noise_memory
    );
}

fn part2_calibration() {
    println!("── Part 2: calibrating GBM drift/volatility with the adjoint ──");
    let truth = [0.7, 0.4];
    let x0 = [1.0];
    let sde = ReplicatedSde::new(Example1, 1);
    let mut theta = vec![0.3, 0.8]; // deliberately wrong start
    let mut adam = Adam::new(2, 0.05);
    let master = PrngKey::from_seed(7);
    let step = StepControl::Steps(200);
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let batch = 16;

    for iter in 0..60u64 {
        let mut grad = vec![0.0; 2];
        let mut loss_acc = 0.0;
        // A batch of replicates of one problem, each on its own Brownian
        // stream derived from the master key; solved thread-parallel.
        let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
        let replicates = prob.replicates(master.fold_in(iter), batch);
        for out in sensitivity_batch(&replicates, &alg, step) {
            // Ones-vector adjoint: dtheta of Σ X_T on this path. Loss
            // (X_T − X*_T)² with X*_T the true model's endpoint on the
            // SAME realized path: d/dθ = 2·resid · dX_T/dθ, and the
            // adjoint output is exactly dX_T/dθ (linearity in ∂L/∂z_T).
            let out = out.expect("adjoint-compatible problem");
            let target = Example1.analytic_solution(1.0, x0[0], &truth, out.w_terminal[0]);
            let resid = out.z_terminal[0] - target;
            loss_acc += resid * resid;
            grad[0] += 2.0 * resid * out.dtheta[0];
            grad[1] += 2.0 * resid * out.dtheta[1];
        }
        for g in grad.iter_mut() {
            *g /= batch as f64;
        }
        adam.step(&mut theta, &grad, 1.0);
        if iter % 10 == 0 {
            println!(
                "iter {iter:>3}: loss {:>10.5}  α {:.3} (→ {})  β {:.3} (→ {})",
                loss_acc / batch as f64,
                theta[0],
                truth[0],
                theta[1],
                truth[1]
            );
        }
    }
    println!(
        "calibrated: α {:.3} vs {:.1}, β {:.3} vs {:.1}",
        theta[0], truth[0], theta[1], truth[1]
    );
    assert!((theta[0] - truth[0]).abs() < 0.15, "α did not converge");
    assert!((theta[1] - truth[1]).abs() < 0.15, "β did not converge");
}

fn part3_batched_monte_carlo() {
    println!("\n── Part 3: batched Monte Carlo on the SoA engine ──");
    let dim = 10;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(5);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let n_paths = 2048;
    let n_steps = 400;

    // One problem, replicated over independent Brownian streams; one call
    // solves the whole fleet (chunks of paths advance together per step).
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let replicates = prob.replicates(PrngKey::from_seed(6), n_paths);
    let opts = SolveOptions::fixed(Method::MilsteinIto, n_steps);

    let t0 = std::time::Instant::now();
    let sols = solve_batch(&replicates, &opts);
    let dt_batched = t0.elapsed().as_secs_f64();
    let mean_x0: f64 =
        sols.iter().map(|s| s.final_state()[0]).sum::<f64>() / n_paths as f64;
    let var_x0: f64 = sols
        .iter()
        .map(|s| (s.final_state()[0] - mean_x0).powi(2))
        .sum::<f64>()
        / (n_paths - 1) as f64;
    println!(
        "E[X_T^(0)] ≈ {mean_x0:.5} ± {:.5}  ({n_paths} paths × {n_steps} steps, {:.1} ms)",
        (var_x0 / n_paths as f64).sqrt(),
        dt_batched * 1e3
    );

    // The same fleet through the pre-0.3 thread-per-path engine: results
    // are bit-identical — only the throughput differs.
    let t0 = std::time::Instant::now();
    let sols_pp = solve_batch_per_path(&replicates, &opts);
    let dt_per_path = t0.elapsed().as_secs_f64();
    assert!(sols.iter().zip(&sols_pp).all(|(a, b)| a.states == b.states));
    println!(
        "per-path engine agrees bit-for-bit ({:.1} ms → {:.2}x)",
        dt_per_path * 1e3,
        dt_per_path / dt_batched.max(1e-12)
    );

    // Batched gradients: the Monte Carlo estimate of ∂E[Σ X_T]/∂θ via the
    // batched augmented adjoint (one [B×(2d+p+1)] backward state per
    // chunk).
    let alg = SensAlg::StochasticAdjoint(AdjointConfig::default());
    let grads = sensitivity_batch(&replicates, &alg, StepControl::Steps(n_steps));
    let mut mean_dtheta = vec![0.0; theta.len()];
    for g in &grads {
        let g = g.as_ref().expect("adjoint-compatible problem");
        for (m, d) in mean_dtheta.iter_mut().zip(&g.dtheta) {
            *m += d / n_paths as f64;
        }
    }
    println!("∂E[Σ X_T]/∂θ[0..3] ≈ {:?}", &mean_dtheta[..3]);
    println!("quickstart OK");
}
