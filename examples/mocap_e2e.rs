//! END-TO-END driver (DESIGN.md deliverable): the full system on a real
//! small workload — the Table 2 experiment.
//!
//! ```bash
//! cargo run --release --example mocap_e2e            # ~minutes
//! cargo run --release --example mocap_e2e -- --full  # paper-scale
//! ```
//!
//! Exercises every layer in one run:
//! * data pipeline: 50-d synthetic mocap, 23 sequences, 16/3/4 split;
//! * model: latent SDE (6-d latent, first-3-frames MLP encoder, per-dim
//!   diffusion nets) and the latent ODE ablation;
//! * training: multi-worker Adam with KL annealing, loss curves logged to
//!   CSV (`bench_out/table2_*_training.csv`);
//! * inference: 50-sample posterior prediction of future frames, test MSE
//!   with 95% CI — the Table 2 protocol.
//!
//! The reproduction claim is the ordering: latent SDE < latent ODE <
//! constant baselines on held-out future-frame MSE.

use sdegrad::coordinator::repro::table2;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let rows = table2::run(!full);

    let mse = |name: &str| {
        rows.iter()
            .find(|r| r.method.contains(name))
            .map(|r| r.test_mse)
            .expect("row missing")
    };
    let sde = mse("SDE");
    let ode = mse("ODE");
    let hold = mse("Hold");
    println!("\nordering check: latent SDE {sde:.4} vs latent ODE {ode:.4} vs hold {hold:.4}");
    if sde < ode && ode < hold {
        println!("paper's ordering REPRODUCED: SDE < ODE < baseline");
    } else if sde < hold {
        println!("partial: SDE beats the baselines; SDE-vs-ODE gap within noise at this scale");
    } else {
        println!("WARNING: ordering not reproduced at this training budget — rerun with --full");
    }
}
