//! Latent SDE on the stochastic Lorenz attractor (§7.2 / Figures 6 & 8).
//!
//! ```bash
//! cargo run --release --example lorenz_latent_sde [-- --full]
//! ```
//!
//! Generates the attractor dataset, trains a latent SDE with the
//! stochastic-adjoint ELBO, and reports: the loss curve, posterior
//! reconstruction MSE, and the spread of prior samples (the paper's
//! headline qualitative claim — the learned prior is genuinely
//! stochastic, producing spread even from a shared initial latent state).

use sdegrad::coordinator::repro::latent_figs;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let summary = latent_figs::run_lorenz(!full);
    println!("\nsummary:");
    println!("  loss: {:.2} → {:.2}", summary.first_loss, summary.last_loss);
    println!("  posterior reconstruction MSE: {:.4}", summary.recon_mse);
    println!("  prior terminal spread (free z0):   {:.4}", summary.prior_spread);
    println!("  prior terminal spread (shared z0): {:.4}", summary.shared_z0_spread);
    println!("\nCSV outputs under bench_out/: fig6_lorenz_training.csv,");
    println!("fig6_lorenz_reconstructions.csv, fig6_lorenz_prior_samples.csv");
    assert!(summary.last_loss < summary.first_loss, "training failed to improve");
}
